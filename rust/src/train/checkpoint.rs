//! Crash-safe, generation-versioned checkpointing with incremental saves.
//!
//! The store is a directory of immutable **generations** (`gen-000001`,
//! `gen-000002`, ...). Every save follows the embedded-database commit
//! discipline ("kill -9 loses nothing"):
//!
//! 1. write every tensor file into a hidden staging directory
//!    (`.staging.gen-N`), fsyncing each file;
//! 2. write a `MANIFEST` **last** — kind, step, shapes, and a CRC32 per
//!    file, the whole manifest self-checksummed on its final line — and
//!    fsync it;
//! 3. fsync the staging directory, then commit with one atomic
//!    `rename(.staging.gen-N, gen-N)`, then fsync the parent.
//!
//! A reader never sees a partial generation: either the rename happened
//! (the manifest inside is complete by construction) or it didn't (the
//! staging directory is garbage, swept on the next [`CheckpointStore::open`]).
//! Recovery walks generations newest-first and loads the first one whose
//! manifest chain validates; torn, truncated, or bit-flipped tensor files
//! are caught by per-file CRCs and reported as typed [`CkptError`]s, never
//! loaded as garbage.
//!
//! **Incremental saves.** After a full base generation, subsequent saves
//! journal only the embedding pages the optimizer dirtied
//! ([`crate::model::DirtyRows`], absorbed per step by
//! [`CheckpointStore::absorb_dirty`] / [`AutoCheckpointer::after_step`]):
//! a delta generation stores the sorted dirty page list (`ent.pages.bin`)
//! plus the packed rows of each page for data and both Adam moments —
//! bounded by `dirty × PAGE_ROWS` rows. Dense params are small and always
//! written whole. Deltas chain to their parent generation; after
//! [`CheckpointConfig::max_delta_chain`] deltas the store compacts back to
//! a full base (and garbage-collects chains older than the previous base).
//! [`CheckpointStore::load_latest`] replays base + deltas to a state
//! bitwise identical to a full save of the same state.
//!
//! **Fault injection.** Every write, fsync, and the commit rename are
//! threaded through [`crate::util::failpoint`] sites (see
//! [`FAILPOINT_SITES`]); `rust/tests/checkpoint_crash.rs` kills a child
//! process at each site and asserts the previous generation always
//! recovers bitwise. [`AutoCheckpointer`] adds trainer-side robustness:
//! cadence saves with retry/backoff on transient I/O errors, and graceful
//! degradation — a permanently failed save logs, counts into
//! [`CheckpointMetrics`], and never poisons the training step.
//!
//! The legacy one-call API ([`save`]/[`load`]) is kept as a thin wrapper:
//! `save` commits one full generation, `load` recovers the latest.

use std::collections::{BTreeMap, BTreeSet, HashMap, HashSet};
use std::fmt;
use std::fs;
use std::io::{BufWriter, Write};
use std::path::{Path, PathBuf};
use std::sync::{Arc, OnceLock};
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::model::pagesource::{PageSource, TableMap, SERVE_ALIGN};
use crate::model::snapshot::SnapshotStatics;
use crate::model::{
    DirtyRows, EmbeddingTable, ModelSnapshot, ModelState, ShardLayout, ShardedTableBuilder,
    PAGE_ROWS,
};
use crate::serve::metrics::{render_histogram, Counter, Histogram, LATENCY_BOUNDS};
use crate::util::failpoint::{self, Fired};

// ---------------------------------------------------------------------------
// failpoint sites
// ---------------------------------------------------------------------------

/// Before writing a tensor/pages payload file (short-write leaves a torn
/// prefix on disk).
pub const FP_WRITE_TENSOR: &str = "ckpt.write.tensor";
/// Before fsyncing a payload file.
pub const FP_SYNC_TENSOR: &str = "ckpt.sync.tensor";
/// Before writing the MANIFEST (short-write leaves a torn manifest).
pub const FP_WRITE_MANIFEST: &str = "ckpt.write.manifest";
/// Before fsyncing the MANIFEST.
pub const FP_SYNC_MANIFEST: &str = "ckpt.sync.manifest";
/// Before fsyncing the staging directory.
pub const FP_SYNC_STAGING: &str = "ckpt.sync.staging";
/// Before the atomic commit rename.
pub const FP_RENAME: &str = "ckpt.commit.rename";
/// Before fsyncing the store root after the rename (the generation is on
/// disk but not yet durable — the save still reports failure).
pub const FP_SYNC_ROOT: &str = "ckpt.sync.root";
/// After the commit fully completed (abort here must recover the *new*
/// generation).
pub const FP_AFTER_COMMIT: &str = "ckpt.after.commit";

/// Every site a save threads through, in commit order — the crash suite
/// kills a subprocess at each of these.
pub const FAILPOINT_SITES: [&str; 8] = [
    FP_WRITE_TENSOR,
    FP_SYNC_TENSOR,
    FP_WRITE_MANIFEST,
    FP_SYNC_MANIFEST,
    FP_SYNC_STAGING,
    FP_RENAME,
    FP_SYNC_ROOT,
    FP_AFTER_COMMIT,
];

// ---------------------------------------------------------------------------
// typed errors
// ---------------------------------------------------------------------------

/// Typed checkpoint errors. Concrete (not stringly) so tests and callers
/// can match on *why* a load refused — a checksum mismatch must never be
/// confused with a merely missing checkpoint.
#[derive(Debug)]
pub enum CkptError {
    /// the store directory holds no committed generation
    NoCheckpoint { root: PathBuf },
    /// an OS-level I/O failure (or an injected one)
    Io { op: &'static str, path: PathBuf, source: std::io::Error },
    /// a generation's MANIFEST is missing fields, mis-checksummed, or
    /// structurally inconsistent with its chain
    ManifestCorrupt { gen: u64, reason: String },
    /// a payload file's bytes do not match the CRC its manifest recorded
    ChecksumMismatch { file: PathBuf, expected: u32, actual: u32 },
    /// a payload file is shorter or longer than its manifest recorded
    LengthMismatch { file: PathBuf, expected_bytes: u64, actual_bytes: u64 },
    /// the checkpoint does not describe this state (model, shapes, or
    /// dense parameter set differ)
    Incompatible { reason: String },
}

impl fmt::Display for CkptError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            CkptError::NoCheckpoint { root } => {
                write!(f, "no checkpoint at {}", root.display())
            }
            CkptError::Io { op, path, source } => {
                write!(f, "{op} {}: {source}", path.display())
            }
            CkptError::ManifestCorrupt { gen, reason } => {
                write!(f, "generation {gen} manifest corrupt: {reason}")
            }
            CkptError::ChecksumMismatch { file, expected, actual } => write!(
                f,
                "{}: checksum mismatch (manifest 0x{expected:08X}, file 0x{actual:08X})",
                file.display()
            ),
            CkptError::LengthMismatch { file, expected_bytes, actual_bytes } => write!(
                f,
                "{}: expected {expected_bytes} bytes, got {actual_bytes}",
                file.display()
            ),
            CkptError::Incompatible { reason } => write!(f, "incompatible checkpoint: {reason}"),
        }
    }
}

impl std::error::Error for CkptError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            CkptError::Io { source, .. } => Some(source),
            _ => None,
        }
    }
}

fn io_err(op: &'static str, path: &Path, source: std::io::Error) -> CkptError {
    CkptError::Io { op, path: path.to_path_buf(), source }
}

fn mf_err(gen: u64, reason: impl Into<String>) -> CkptError {
    CkptError::ManifestCorrupt { gen, reason: reason.into() }
}

// ---------------------------------------------------------------------------
// CRC32 (IEEE, reflected — the zlib/PNG polynomial)
// ---------------------------------------------------------------------------

fn crc32_table() -> &'static [u32; 256] {
    static TABLE: OnceLock<[u32; 256]> = OnceLock::new();
    TABLE.get_or_init(|| {
        let mut table = [0u32; 256];
        for (i, slot) in table.iter_mut().enumerate() {
            let mut c = i as u32;
            for _ in 0..8 {
                c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            }
            *slot = c;
        }
        table
    })
}

/// Streaming CRC32 state (payload files are written in chunks).
#[derive(Clone, Copy)]
struct Crc32(u32);

impl Crc32 {
    fn new() -> Crc32 {
        Crc32(0xFFFF_FFFF)
    }

    fn update(&mut self, bytes: &[u8]) {
        let table = crc32_table();
        for &b in bytes {
            self.0 = table[((self.0 ^ b as u32) & 0xFF) as usize] ^ (self.0 >> 8);
        }
    }

    fn finish(self) -> u32 {
        self.0 ^ 0xFFFF_FFFF
    }
}

fn crc32(bytes: &[u8]) -> u32 {
    let mut c = Crc32::new();
    c.update(bytes);
    c.finish()
}

// ---------------------------------------------------------------------------
// manifest
// ---------------------------------------------------------------------------

const MANIFEST_MAGIC: &str = "ngdb-ckpt-v1";

/// What a committed generation contains.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SaveKind {
    /// every tensor, whole
    Full,
    /// dirty pages of the embedding tables + whole dense params, chained
    /// to a parent generation
    Delta,
}

impl SaveKind {
    pub fn as_str(&self) -> &'static str {
        match self {
            SaveKind::Full => "full",
            SaveKind::Delta => "delta",
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
struct FileMeta {
    bytes: u64,
    crc: u32,
}

/// Parsed `MANIFEST` of one generation.
#[derive(Debug, Clone)]
pub struct GenManifest {
    pub gen: u64,
    pub kind: SaveKind,
    pub step: u64,
    pub model: String,
    pub ent_rows: usize,
    pub ent_dim: usize,
    pub rel_rows: usize,
    pub rel_dim: usize,
    pub repr_dim: usize,
    /// dense parameter names, in state (sorted) order
    pub dense: Vec<String>,
    /// delta only: the generation this delta patches
    pub parent: u64,
    /// delta only: the full generation the chain is rooted at
    pub base: u64,
    /// delta only: 1-based position in the chain
    pub chain: usize,
    /// full generations with a serve layout: the shard count the
    /// `{tag}.serve.bin` companion files are laid out for (`None` on
    /// generations written without [`CheckpointConfig::serve_layout`] —
    /// manifest v1 readers ignore the extra keys, so both directions stay
    /// compatible)
    pub serve_shards: Option<usize>,
    /// byte alignment each serve-file shard section is padded to
    pub serve_align: Option<usize>,
    files: BTreeMap<String, FileMeta>,
}

fn render_manifest(m: &GenManifest) -> String {
    let mut s = String::with_capacity(512);
    s.push_str(MANIFEST_MAGIC);
    s.push('\n');
    s.push_str(&format!("kind={}\n", m.kind.as_str()));
    s.push_str(&format!("gen={}\n", m.gen));
    s.push_str(&format!("step={}\n", m.step));
    s.push_str(&format!("model={}\n", m.model));
    s.push_str(&format!(
        "ent_rows={}\nent_dim={}\nrel_rows={}\nrel_dim={}\nrepr_dim={}\n",
        m.ent_rows, m.ent_dim, m.rel_rows, m.rel_dim, m.repr_dim
    ));
    s.push_str(&format!("dense={}\n", m.dense.join(",")));
    if m.kind == SaveKind::Delta {
        s.push_str(&format!("parent={}\nbase={}\nchain={}\n", m.parent, m.base, m.chain));
    }
    if let Some(n) = m.serve_shards {
        s.push_str(&format!("serve_shards={n}\n"));
    }
    if let Some(a) = m.serve_align {
        s.push_str(&format!("serve_align={a}\n"));
    }
    for (name, f) in &m.files {
        s.push_str(&format!("file={name} {} 0x{:08X}\n", f.bytes, f.crc));
    }
    s
}

fn parse_manifest(text: &str, expect_gen: u64) -> Result<GenManifest, CkptError> {
    let gen = expect_gen;
    let pos = text
        .rfind("\ncrc=")
        .ok_or_else(|| mf_err(gen, "missing trailing crc line"))?;
    let content = &text[..pos + 1];
    let crc_line = text[pos + 1..].trim_end();
    let declared = crc_line
        .strip_prefix("crc=0x")
        .and_then(|h| u32::from_str_radix(h, 16).ok())
        .ok_or_else(|| mf_err(gen, format!("bad crc line {crc_line:?}")))?;
    let actual = crc32(content.as_bytes());
    if actual != declared {
        return Err(mf_err(
            gen,
            format!("manifest checksum mismatch (declared 0x{declared:08X}, computed 0x{actual:08X})"),
        ));
    }

    let mut lines = content.lines();
    if lines.next() != Some(MANIFEST_MAGIC) {
        return Err(mf_err(gen, "bad magic"));
    }
    let mut kv: HashMap<&str, &str> = HashMap::new();
    let mut files = BTreeMap::new();
    for line in lines {
        let Some((k, v)) = line.split_once('=') else {
            return Err(mf_err(gen, format!("malformed line {line:?}")));
        };
        if k == "file" {
            let mut parts = v.split_whitespace();
            let (Some(name), Some(bytes), Some(crc_hex)) =
                (parts.next(), parts.next(), parts.next())
            else {
                return Err(mf_err(gen, format!("malformed file entry {v:?}")));
            };
            let bytes: u64 =
                bytes.parse().map_err(|_| mf_err(gen, format!("bad file size {bytes:?}")))?;
            let crc = crc_hex
                .strip_prefix("0x")
                .and_then(|h| u32::from_str_radix(h, 16).ok())
                .ok_or_else(|| mf_err(gen, format!("bad file crc {crc_hex:?}")))?;
            files.insert(name.to_string(), FileMeta { bytes, crc });
        } else {
            kv.insert(k, v);
        }
    }
    let get = |k: &str| kv.get(k).copied().ok_or_else(|| mf_err(gen, format!("missing {k}")));
    let num = |k: &str| -> Result<u64, CkptError> {
        get(k)?.parse().map_err(|_| mf_err(gen, format!("non-numeric {k}")))
    };
    let kind = match get("kind")? {
        "full" => SaveKind::Full,
        "delta" => SaveKind::Delta,
        other => return Err(mf_err(gen, format!("unknown kind {other:?}"))),
    };
    if num("gen")? != expect_gen {
        return Err(mf_err(gen, "manifest gen does not match its directory"));
    }
    let (parent, base, chain) = match kind {
        SaveKind::Full => (0, expect_gen, 0),
        SaveKind::Delta => (num("parent")?, num("base")?, num("chain")? as usize),
    };
    // optional serve-layout keys (absent on pre-mmap generations)
    let opt_num = |k: &str| -> Result<Option<usize>, CkptError> {
        match kv.get(k) {
            Some(v) => {
                v.parse().map(Some).map_err(|_| mf_err(gen, format!("non-numeric {k}")))
            }
            None => Ok(None),
        }
    };
    Ok(GenManifest {
        gen: expect_gen,
        kind,
        step: num("step")?,
        model: get("model")?.to_string(),
        ent_rows: num("ent_rows")? as usize,
        ent_dim: num("ent_dim")? as usize,
        rel_rows: num("rel_rows")? as usize,
        rel_dim: num("rel_dim")? as usize,
        repr_dim: num("repr_dim")? as usize,
        dense: get("dense")?
            .split(',')
            .filter(|s| !s.is_empty())
            .map(str::to_string)
            .collect(),
        parent,
        base,
        chain,
        serve_shards: opt_num("serve_shards")?,
        serve_align: opt_num("serve_align")?,
        files,
    })
}

// ---------------------------------------------------------------------------
// state identity (what a delta chain must hold constant)
// ---------------------------------------------------------------------------

#[derive(Debug, Clone, PartialEq, Eq)]
struct Identity {
    model: String,
    ent_rows: usize,
    ent_dim: usize,
    rel_rows: usize,
    rel_dim: usize,
    repr_dim: usize,
    dense: Vec<String>,
}

impl Identity {
    fn of_state(state: &ModelState) -> Identity {
        Identity {
            model: state.model.clone(),
            ent_rows: state.entities.rows,
            ent_dim: state.entities.dim,
            rel_rows: state.relations.rows,
            rel_dim: state.relations.dim,
            repr_dim: state.repr_dim,
            dense: state.dense.keys().cloned().collect(),
        }
    }

    fn of_manifest(m: &GenManifest) -> Identity {
        Identity {
            model: m.model.clone(),
            ent_rows: m.ent_rows,
            ent_dim: m.ent_dim,
            rel_rows: m.rel_rows,
            rel_dim: m.rel_dim,
            repr_dim: m.repr_dim,
            dense: m.dense.clone(),
        }
    }
}

/// Full compatibility check of a checkpoint against an initialized state:
/// model, entity *and* relation shapes, repr width, and the exact dense
/// parameter name set (an extra or missing dense param is a refusal, not a
/// silent skip).
fn check_compatible(m: &GenManifest, state: &ModelState) -> Result<(), CkptError> {
    let refuse = |reason: String| Err(CkptError::Incompatible { reason });
    if m.model != state.model {
        return refuse(format!("checkpoint is for model {:?}, state is {:?}", m.model, state.model));
    }
    if m.ent_rows != state.entities.rows || m.ent_dim != state.entities.dim {
        return refuse(format!(
            "entity table shape mismatch: checkpoint {}x{}, state {}x{}",
            m.ent_rows, m.ent_dim, state.entities.rows, state.entities.dim
        ));
    }
    if m.rel_rows != state.relations.rows || m.rel_dim != state.relations.dim {
        return refuse(format!(
            "relation table shape mismatch: checkpoint {}x{}, state {}x{}",
            m.rel_rows, m.rel_dim, state.relations.rows, state.relations.dim
        ));
    }
    if m.repr_dim != state.repr_dim {
        return refuse(format!(
            "repr_dim mismatch: checkpoint {}, state {}",
            m.repr_dim, state.repr_dim
        ));
    }
    let state_dense: Vec<&String> = state.dense.keys().collect();
    if m.dense.iter().collect::<Vec<_>>() != state_dense {
        return refuse(format!(
            "dense param set mismatch: checkpoint has [{}], state has [{}]",
            m.dense.join(", "),
            state.dense.keys().cloned().collect::<Vec<_>>().join(", ")
        ));
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// fault-injected file primitives
// ---------------------------------------------------------------------------

/// Stream `slices` to `path` as little-endian f32s through a fixed stack
/// buffer (O(1) memory — a checkpoint must not double peak RSS), CRC'ing
/// as it goes. An injected short write flushes the torn prefix to disk,
/// then errors.
fn write_f32_slices(path: &Path, slices: &[&[f32]]) -> Result<FileMeta, CkptError> {
    const CHUNK: usize = 4096;
    let total: u64 = slices.iter().map(|s| s.len() as u64 * 4).sum();
    let cap = match failpoint::check(FP_WRITE_TENSOR) {
        Some(Fired::Error) => {
            return Err(io_err("writing", path, failpoint::injected_io_error(FP_WRITE_TENSOR)))
        }
        Some(Fired::ShortWrite) => total / 2,
        None => u64::MAX,
    };
    let file = fs::File::create(path).map_err(|e| io_err("creating", path, e))?;
    let mut w = BufWriter::new(file);
    let mut crc = Crc32::new();
    let mut written = 0u64;
    let mut buf = [0u8; CHUNK * 4];
    'slices: for s in slices {
        for chunk in s.chunks(CHUNK) {
            let bytes = &mut buf[..chunk.len() * 4];
            for (b, x) in bytes.chunks_exact_mut(4).zip(chunk) {
                b.copy_from_slice(&x.to_le_bytes());
            }
            let take = (bytes.len() as u64).min(cap - written) as usize;
            w.write_all(&bytes[..take]).map_err(|e| io_err("writing", path, e))?;
            crc.update(&bytes[..take]);
            written += take as u64;
            if written >= cap {
                break 'slices;
            }
        }
    }
    w.flush().map_err(|e| io_err("flushing", path, e))?;
    let file = w.into_inner().map_err(|e| io_err("flushing", path, e.into_error()))?;
    if written < total {
        let _ = file.sync_all(); // make the torn prefix real before failing
        return Err(io_err(
            "writing (injected short write)",
            path,
            failpoint::injected_io_error(FP_WRITE_TENSOR),
        ));
    }
    if failpoint::check(FP_SYNC_TENSOR).is_some() {
        return Err(io_err("fsyncing", path, failpoint::injected_io_error(FP_SYNC_TENSOR)));
    }
    file.sync_all().map_err(|e| io_err("fsyncing", path, e))?;
    Ok(FileMeta { bytes: total, crc: crc.finish() })
}

/// Little-endian u32 payload (delta page lists — always small).
fn write_u32_file(path: &Path, vals: &[u32]) -> Result<FileMeta, CkptError> {
    let mut bytes = Vec::with_capacity(vals.len() * 4);
    for v in vals {
        bytes.extend_from_slice(&v.to_le_bytes());
    }
    let total = bytes.len() as u64;
    let cap = match failpoint::check(FP_WRITE_TENSOR) {
        Some(Fired::Error) => {
            return Err(io_err("writing", path, failpoint::injected_io_error(FP_WRITE_TENSOR)))
        }
        Some(Fired::ShortWrite) => total / 2,
        None => total,
    };
    let take = total.min(cap) as usize;
    let mut file = fs::File::create(path).map_err(|e| io_err("creating", path, e))?;
    file.write_all(&bytes[..take]).map_err(|e| io_err("writing", path, e))?;
    if (take as u64) < total {
        let _ = file.sync_all();
        return Err(io_err(
            "writing (injected short write)",
            path,
            failpoint::injected_io_error(FP_WRITE_TENSOR),
        ));
    }
    if failpoint::check(FP_SYNC_TENSOR).is_some() {
        return Err(io_err("fsyncing", path, failpoint::injected_io_error(FP_SYNC_TENSOR)));
    }
    file.sync_all().map_err(|e| io_err("fsyncing", path, e))?;
    Ok(FileMeta { bytes: total, crc: crc32(&bytes) })
}

/// Serve-layout companion file: shard-major, each shard's rows packed
/// local-contiguously (exactly the order [`crate::model::ShardedTable`]
/// pages read), and every shard section zero-padded to a [`SERVE_ALIGN`]
/// boundary so each mapped shard window starts OS-page-aligned. Goes
/// through the same fault-injected primitive as every tensor file, so the
/// crash suite's coverage extends to it for free.
fn write_serve_layout(
    path: &Path,
    t: &EmbeddingTable,
    n_shards: usize,
) -> Result<FileMeta, CkptError> {
    let layout = ShardLayout::new(n_shards);
    let zeros = [0f32; SERVE_ALIGN / 4];
    let mut slices: Vec<&[f32]> = Vec::new();
    for s in 0..n_shards {
        let rows = layout.shard_rows(t.rows, s);
        for l in 0..rows {
            slices.push(t.row(layout.global_of(s, l)));
        }
        let section = rows * t.dim * 4;
        let pad = (section.next_multiple_of(SERVE_ALIGN) - section) / 4;
        slices.push(&zeros[..pad]);
    }
    write_f32_slices(path, &slices)
}

/// Byte length [`write_serve_layout`] produces for a `rows × dim` table
/// over `n_shards` at section alignment `align` — the loader cross-checks
/// the manifest against it so a layout/shape disagreement is a typed
/// refusal, not a bad window.
fn serve_layout_bytes(rows: usize, dim: usize, n_shards: usize, align: usize) -> u64 {
    let layout = ShardLayout::new(n_shards);
    (0..n_shards)
        .map(|s| (layout.shard_rows(rows, s) * dim * 4).next_multiple_of(align) as u64)
        .sum()
}

/// Write the self-checksummed MANIFEST (the commit record — always last).
fn write_manifest(dir: &Path, m: &GenManifest) -> Result<(), CkptError> {
    let content = render_manifest(m);
    let full = format!("{content}crc=0x{:08X}\n", crc32(content.as_bytes()));
    let path = dir.join("MANIFEST");
    let cap = match failpoint::check(FP_WRITE_MANIFEST) {
        Some(Fired::Error) => {
            return Err(io_err("writing", &path, failpoint::injected_io_error(FP_WRITE_MANIFEST)))
        }
        Some(Fired::ShortWrite) => full.len() / 2,
        None => full.len(),
    };
    fs::write(&path, &full.as_bytes()[..cap]).map_err(|e| io_err("writing", &path, e))?;
    if cap < full.len() {
        return Err(io_err(
            "writing (injected short write)",
            &path,
            failpoint::injected_io_error(FP_WRITE_MANIFEST),
        ));
    }
    if failpoint::check(FP_SYNC_MANIFEST).is_some() {
        return Err(io_err("fsyncing", &path, failpoint::injected_io_error(FP_SYNC_MANIFEST)));
    }
    let file = fs::File::open(&path).map_err(|e| io_err("fsyncing", &path, e))?;
    file.sync_all().map_err(|e| io_err("fsyncing", &path, e))?;
    Ok(())
}

/// fsync a directory so a just-created/renamed entry survives power loss
/// (POSIX: the rename itself is atomic, but only the directory fsync makes
/// it durable).
fn fsync_dir(path: &Path, site: &'static str) -> Result<(), CkptError> {
    if failpoint::check(site).is_some() {
        return Err(io_err("fsyncing dir", path, failpoint::injected_io_error(site)));
    }
    #[cfg(unix)]
    {
        let f = fs::File::open(path).map_err(|e| io_err("opening dir", path, e))?;
        f.sync_all().map_err(|e| io_err("fsyncing dir", path, e))?;
    }
    #[cfg(not(unix))]
    let _ = path;
    Ok(())
}

/// Read a payload file and verify it byte-for-byte against its manifest
/// entry: exact length (torn/truncated/padded files), then CRC32
/// (bit flips), then the shape the caller expects.
fn read_verified(
    dir: &Path,
    m: &GenManifest,
    name: &str,
    expect_bytes: u64,
) -> Result<Vec<u8>, CkptError> {
    let meta = m
        .files
        .get(name)
        .ok_or_else(|| mf_err(m.gen, format!("missing file entry for {name}")))?;
    let path = dir.join(name);
    let bytes = fs::read(&path).map_err(|e| io_err("reading", &path, e))?;
    if bytes.len() as u64 != meta.bytes {
        return Err(CkptError::LengthMismatch {
            file: path,
            expected_bytes: meta.bytes,
            actual_bytes: bytes.len() as u64,
        });
    }
    let actual = crc32(&bytes);
    if actual != meta.crc {
        return Err(CkptError::ChecksumMismatch { file: path, expected: meta.crc, actual });
    }
    if bytes.len() as u64 != expect_bytes {
        return Err(CkptError::LengthMismatch {
            file: path,
            expected_bytes: expect_bytes,
            actual_bytes: bytes.len() as u64,
        });
    }
    Ok(bytes)
}

fn read_f32_verified(
    dir: &Path,
    m: &GenManifest,
    name: &str,
    n: usize,
) -> Result<Vec<f32>, CkptError> {
    let bytes = read_verified(dir, m, name, n as u64 * 4)?;
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

fn read_u32_verified(dir: &Path, m: &GenManifest, name: &str) -> Result<Vec<u32>, CkptError> {
    let meta = m
        .files
        .get(name)
        .ok_or_else(|| mf_err(m.gen, format!("missing file entry for {name}")))?;
    let bytes = read_verified(dir, m, name, meta.bytes)?;
    if bytes.len() % 4 != 0 {
        return Err(mf_err(m.gen, format!("{name}: size not a multiple of 4")));
    }
    Ok(bytes.chunks_exact(4).map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect())
}

// ---------------------------------------------------------------------------
// generation scan / chain resolution
// ---------------------------------------------------------------------------

fn gen_dir_name(gen: u64) -> String {
    format!("gen-{gen:06}")
}

fn scan_gens(root: &Path) -> Vec<u64> {
    let mut ids = Vec::new();
    if let Ok(entries) = fs::read_dir(root) {
        for entry in entries.flatten() {
            let name = entry.file_name();
            if let Some(id) = name.to_str().and_then(|n| n.strip_prefix("gen-")) {
                if let Ok(id) = id.parse::<u64>() {
                    ids.push(id);
                }
            }
        }
    }
    ids.sort_unstable();
    ids
}

fn read_gen_manifest(root: &Path, gen: u64) -> Result<GenManifest, CkptError> {
    let path = root.join(gen_dir_name(gen)).join("MANIFEST");
    let text = fs::read_to_string(&path).map_err(|e| io_err("reading", &path, e))?;
    parse_manifest(&text, gen)
}

/// Walk one candidate generation's delta chain back to its full base,
/// validating every manifest and link. Returns the chain base-first.
fn try_chain(root: &Path, gen: u64) -> Result<Vec<GenManifest>, CkptError> {
    let mut chain = vec![read_gen_manifest(root, gen)?];
    while chain.last().unwrap().kind == SaveKind::Delta {
        let cur = chain.last().unwrap();
        if chain.len() > 4096 {
            return Err(mf_err(cur.gen, "delta chain too long (cycle?)"));
        }
        let parent = read_gen_manifest(root, cur.parent)?;
        if parent.gen >= cur.gen || parent.step > cur.step {
            return Err(mf_err(cur.gen, "parent generation is not older than its delta"));
        }
        let link_ok = match parent.kind {
            SaveKind::Full => parent.gen == cur.base && cur.chain == 1,
            SaveKind::Delta => parent.base == cur.base && parent.chain + 1 == cur.chain,
        };
        if !link_ok {
            return Err(mf_err(cur.gen, "broken base/chain link to parent"));
        }
        if Identity::of_manifest(&parent) != Identity::of_manifest(cur) {
            return Err(mf_err(cur.gen, "chain identity mismatch (shapes changed mid-chain)"));
        }
        chain.push(parent);
    }
    chain.reverse();
    Ok(chain)
}

/// Newest loadable chain in `root`, base-first: generations are tried
/// newest-first and the first one whose whole manifest chain validates
/// wins — a torn manifest (kill mid-save would never leave one, but disk
/// damage can) silently falls back to the previous generation.
fn resolve_chain(root: &Path) -> Result<Vec<GenManifest>, CkptError> {
    let ids = scan_gens(root);
    if ids.is_empty() {
        return Err(CkptError::NoCheckpoint { root: root.to_path_buf() });
    }
    let mut first_err = None;
    for &gen in ids.iter().rev() {
        match try_chain(root, gen) {
            Ok(chain) => return Ok(chain),
            Err(e) => {
                if first_err.is_none() {
                    first_err = Some(e);
                }
            }
        }
    }
    Err(first_err.unwrap())
}

// ---------------------------------------------------------------------------
// the store
// ---------------------------------------------------------------------------

/// Tuning knobs of a [`CheckpointStore`].
#[derive(Debug, Clone)]
pub struct CheckpointConfig {
    /// deltas allowed after a full base before the store compacts back to
    /// a full save (0 = every save is full)
    pub max_delta_chain: usize,
    /// `Some(n)`: every full save also writes page-aligned, shard-major
    /// `{tag}.serve.bin` companion files laid out for `n` serve shards, so
    /// [`CheckpointStore::load_snapshot_mapped`] can serve straight off a
    /// read-only mapping of the generation. `None` (the default) keeps the
    /// pre-mmap on-disk format and payload sizes.
    pub serve_layout: Option<usize>,
}

impl Default for CheckpointConfig {
    fn default() -> CheckpointConfig {
        CheckpointConfig { max_delta_chain: 8, serve_layout: None }
    }
}

/// The last successfully committed generation — what the next delta
/// chains to. In-memory only: after a fresh [`CheckpointStore::open`] the
/// store cannot know which rows changed since the on-disk chain, so the
/// first save is always full.
#[derive(Debug)]
struct Anchor {
    gen: u64,
    step: u64,
    base: u64,
    chain: usize,
    ident: Identity,
}

/// Outcome of one committed save.
#[derive(Debug, Clone)]
pub struct SaveReport {
    pub gen: u64,
    pub kind: SaveKind,
    /// bytes across all payload files (tensors + page lists; MANIFEST
    /// excluded) — deterministic for a given state/dirt pattern
    pub payload_bytes: u64,
    /// embedding rows serialized (full: all rows; delta: patched rows)
    pub rows_written: u64,
    /// payload files written
    pub files: usize,
}

/// A crash-safe, generation-versioned checkpoint store rooted at one
/// directory. See the module docs for the commit protocol and layout.
#[derive(Debug)]
pub struct CheckpointStore {
    root: PathBuf,
    cfg: CheckpointConfig,
    /// dirty rows accumulated since the last committed save (the union of
    /// every absorbed [`DirtyRows`] — survives failed saves)
    pending_ent: HashSet<u32>,
    pending_rel: HashSet<u32>,
    anchor: Option<Anchor>,
    /// base generation of the previous chain; when a new full base
    /// commits, everything older is garbage-collected
    last_base: Option<u64>,
}

impl CheckpointStore {
    /// Open (or designate) a store at `root`. Does not create the
    /// directory (the first save does); sweeps any `.staging.*` wreckage a
    /// killed writer left behind. Never fails: a missing or unreadable
    /// root simply means "no checkpoint yet" on load and is (re)created on
    /// save.
    pub fn open(root: impl AsRef<Path>) -> CheckpointStore {
        let root = root.as_ref().to_path_buf();
        if let Ok(entries) = fs::read_dir(&root) {
            for entry in entries.flatten() {
                if entry.file_name().to_string_lossy().starts_with(".staging.") {
                    let _ = fs::remove_dir_all(entry.path());
                }
            }
        }
        CheckpointStore {
            root,
            cfg: CheckpointConfig::default(),
            pending_ent: HashSet::new(),
            pending_rel: HashSet::new(),
            anchor: None,
            last_base: None,
        }
    }

    pub fn with_config(mut self, cfg: CheckpointConfig) -> CheckpointStore {
        self.cfg = cfg;
        self
    }

    pub fn root(&self) -> &Path {
        &self.root
    }

    /// Fold one step's dirty-row accounting into the pending set. Call
    /// this *before* anything resets the state's dirty sets (the snapshot
    /// publish path does, every step) — [`AutoCheckpointer::after_step`]
    /// sits at exactly that point in the trainer loop.
    pub fn absorb_dirty(&mut self, dirty: &DirtyRows) {
        self.pending_ent.extend(dirty.ent.iter().copied());
        self.pending_rel.extend(dirty.rel.iter().copied());
    }

    /// Pending (entity, relation) dirty-row counts.
    pub fn pending_rows(&self) -> (usize, usize) {
        (self.pending_ent.len(), self.pending_rel.len())
    }

    /// Drop the delta anchor: the next save is a full base regardless of
    /// chain length (manual compaction, or after out-of-band state
    /// surgery the dirty sets did not record).
    pub fn invalidate_anchor(&mut self) {
        self.anchor = None;
    }

    fn delta_parent(&self, ident: &Identity, step: u64) -> Option<(u64, u64, usize)> {
        match &self.anchor {
            Some(a)
                if a.ident == *ident && a.chain < self.cfg.max_delta_chain && a.step <= step =>
            {
                Some((a.gen, a.base, a.chain))
            }
            _ => None,
        }
    }

    /// What the next [`CheckpointStore::save`] would commit — retry loops
    /// use this to attribute failures to the right `kind` label.
    pub fn next_kind(&self, state: &ModelState) -> SaveKind {
        if self.delta_parent(&Identity::of_state(state), state.step).is_some() {
            SaveKind::Delta
        } else {
            SaveKind::Full
        }
    }

    /// Commit one generation (full, or a delta journal of the pending
    /// dirty pages when a valid anchor exists). On error nothing is
    /// committed, the staging directory is swept, and the pending dirty
    /// set is retained for the retry.
    pub fn save(&mut self, state: &ModelState) -> Result<SaveReport, CkptError> {
        let ident = Identity::of_state(state);
        let delta = self.delta_parent(&ident, state.step);
        fs::create_dir_all(&self.root)
            .map_err(|e| io_err("creating checkpoint root", &self.root, e))?;
        let gen = scan_gens(&self.root).last().copied().unwrap_or(0) + 1;
        let staging = self.root.join(format!(".staging.{}", gen_dir_name(gen)));
        if staging.exists() {
            let _ = fs::remove_dir_all(&staging);
        }
        fs::create_dir_all(&staging).map_err(|e| io_err("creating staging dir", &staging, e))?;

        match self.write_generation(state, &staging, gen, delta) {
            Ok(report) => {
                if report.kind == SaveKind::Full {
                    if let Some(prev_base) = self.last_base {
                        // the new base supersedes the chain *before* the
                        // previous one; keep current + previous for safety
                        for old in scan_gens(&self.root) {
                            if old < prev_base {
                                let _ = fs::remove_dir_all(self.root.join(gen_dir_name(old)));
                            }
                        }
                    }
                    self.last_base = Some(gen);
                }
                let (base, chain) = match (report.kind, delta) {
                    (SaveKind::Full, _) => (gen, 0),
                    (SaveKind::Delta, Some((_, base, chain))) => (base, chain + 1),
                    (SaveKind::Delta, None) => unreachable!("delta save without an anchor"),
                };
                self.anchor = Some(Anchor { gen, step: state.step, base, chain, ident });
                self.pending_ent.clear();
                self.pending_rel.clear();
                Ok(report)
            }
            Err(e) => {
                // after a successful rename the staging path no longer
                // exists and this is a no-op — the committed generation
                // (orphaned by the reported failure) stays on disk and is
                // simply superseded by the retry's higher generation
                let _ = fs::remove_dir_all(&staging);
                Err(e)
            }
        }
    }

    fn write_generation(
        &self,
        state: &ModelState,
        staging: &Path,
        gen: u64,
        delta: Option<(u64, u64, usize)>,
    ) -> Result<SaveReport, CkptError> {
        let mut files: BTreeMap<String, FileMeta> = BTreeMap::new();
        let mut rows_written = 0u64;
        let kind = if delta.is_some() { SaveKind::Delta } else { SaveKind::Full };

        match kind {
            SaveKind::Full => {
                for (tag, t) in [("ent", &state.entities), ("rel", &state.relations)] {
                    for (suffix, field) in [("data", &t.data), ("m", &t.m), ("v", &t.v)] {
                        let name = format!("{tag}.{suffix}.bin");
                        let meta = write_f32_slices(&staging.join(&name), &[field])?;
                        files.insert(name, meta);
                    }
                    rows_written += t.rows as u64;
                    if let Some(n) = self.cfg.serve_layout {
                        let name = format!("{tag}.serve.bin");
                        let meta = write_serve_layout(&staging.join(&name), t, n)?;
                        files.insert(name, meta);
                    }
                }
            }
            SaveKind::Delta => {
                for (tag, t, pending) in [
                    ("ent", &state.entities, &self.pending_ent),
                    ("rel", &state.relations, &self.pending_rel),
                ] {
                    let pages = dirty_pages(pending, t.rows);
                    if pages.is_empty() {
                        continue;
                    }
                    let name = format!("{tag}.pages.bin");
                    let meta = write_u32_file(&staging.join(&name), &pages)?;
                    files.insert(name, meta);
                    let page_span = |p: u32| {
                        let start = p as usize * PAGE_ROWS;
                        (start, (start + PAGE_ROWS).min(t.rows))
                    };
                    for (suffix, field) in [("data", &t.data), ("m", &t.m), ("v", &t.v)] {
                        let slices: Vec<&[f32]> = pages
                            .iter()
                            .map(|&p| {
                                let (start, end) = page_span(p);
                                &field[start * t.dim..end * t.dim]
                            })
                            .collect();
                        let name = format!("{tag}.delta.{suffix}.bin");
                        let meta = write_f32_slices(&staging.join(&name), &slices)?;
                        files.insert(name, meta);
                    }
                    rows_written += pages
                        .iter()
                        .map(|&p| {
                            let (start, end) = page_span(p);
                            (end - start) as u64
                        })
                        .sum::<u64>();
                }
            }
        }
        // dense params are tiny relative to the tables: always whole
        for (name, p) in &state.dense {
            let fname = name.replace('.', "_");
            for (suffix, field) in [("data", &p.data), ("m", &p.m), ("v", &p.v)] {
                let name = format!("dense.{fname}.{suffix}.bin");
                let meta = write_f32_slices(&staging.join(&name), &[field])?;
                files.insert(name, meta);
            }
        }

        let payload_bytes = files.values().map(|f| f.bytes).sum();
        let n_files = files.len();
        let (parent, base, chain) = match delta {
            Some((parent, base, chain)) => (parent, base, chain + 1),
            None => (0, gen, 0),
        };
        let (serve_shards, serve_align) = match (kind, self.cfg.serve_layout) {
            (SaveKind::Full, Some(n)) => (Some(n), Some(SERVE_ALIGN)),
            _ => (None, None),
        };
        let manifest = GenManifest {
            gen,
            kind,
            step: state.step,
            model: state.model.clone(),
            ent_rows: state.entities.rows,
            ent_dim: state.entities.dim,
            rel_rows: state.relations.rows,
            rel_dim: state.relations.dim,
            repr_dim: state.repr_dim,
            dense: state.dense.keys().cloned().collect(),
            parent,
            base,
            chain,
            serve_shards,
            serve_align,
            files,
        };
        write_manifest(staging, &manifest)?;
        fsync_dir(staging, FP_SYNC_STAGING)?;

        // ---- the commit point ------------------------------------------
        let committed = self.root.join(gen_dir_name(gen));
        if failpoint::check(FP_RENAME).is_some() {
            return Err(io_err("renaming", &committed, failpoint::injected_io_error(FP_RENAME)));
        }
        fs::rename(staging, &committed)
            .map_err(|e| io_err("committing (rename)", &committed, e))?;
        fsync_dir(&self.root, FP_SYNC_ROOT)?;
        if failpoint::check(FP_AFTER_COMMIT).is_some() {
            return Err(io_err(
                "after-commit",
                &committed,
                failpoint::injected_io_error(FP_AFTER_COMMIT),
            ));
        }
        Ok(SaveReport { gen, kind, payload_bytes, rows_written, files: n_files })
    }

    /// Recover the newest committed generation into `state` (replaying
    /// base + deltas for a result bitwise identical to a full save),
    /// verifying every payload file's length and CRC. Returns the loaded
    /// generation id. The state's dirty tracking is invalidated: the next
    /// snapshot publish must be a full capture.
    pub fn load_latest(&self, state: &mut ModelState) -> Result<u64, CkptError> {
        let chain = resolve_chain(&self.root)?;
        let latest = chain.last().expect("resolve_chain never returns empty");
        check_compatible(latest, state)?;

        for m in &chain {
            let dir = self.root.join(gen_dir_name(m.gen));
            match m.kind {
                SaveKind::Full => {
                    for (tag, t) in [("ent", &mut state.entities), ("rel", &mut state.relations)]
                    {
                        let n = t.rows * t.dim;
                        t.data = read_f32_verified(&dir, m, &format!("{tag}.data.bin"), n)?;
                        t.m = read_f32_verified(&dir, m, &format!("{tag}.m.bin"), n)?;
                        t.v = read_f32_verified(&dir, m, &format!("{tag}.v.bin"), n)?;
                    }
                }
                SaveKind::Delta => {
                    for (tag, t) in [("ent", &mut state.entities), ("rel", &mut state.relations)]
                    {
                        let pages_name = format!("{tag}.pages.bin");
                        if !m.files.contains_key(&pages_name) {
                            continue; // no rows of this table were dirty
                        }
                        let pages = read_u32_verified(&dir, m, &pages_name)?;
                        if !pages.windows(2).all(|w| w[0] < w[1]) {
                            return Err(mf_err(m.gen, format!("{pages_name}: unsorted pages")));
                        }
                        let n: usize = pages
                            .iter()
                            .map(|&p| {
                                let start = p as usize * PAGE_ROWS;
                                (start + PAGE_ROWS).min(t.rows).saturating_sub(start) * t.dim
                            })
                            .sum();
                        let data =
                            read_f32_verified(&dir, m, &format!("{tag}.delta.data.bin"), n)?;
                        let mm = read_f32_verified(&dir, m, &format!("{tag}.delta.m.bin"), n)?;
                        let vv = read_f32_verified(&dir, m, &format!("{tag}.delta.v.bin"), n)?;
                        apply_page_patch(t, &pages, &data, &mm, &vv, m.gen)?;
                    }
                }
            }
        }
        // dense params are written whole every generation: latest wins
        let latest_dir = self.root.join(gen_dir_name(latest.gen));
        for (name, p) in &mut state.dense {
            let fname = name.replace('.', "_");
            let n = p.data.len();
            p.data =
                read_f32_verified(&latest_dir, latest, &format!("dense.{fname}.data.bin"), n)?;
            p.m = read_f32_verified(&latest_dir, latest, &format!("dense.{fname}.m.bin"), n)?;
            p.v = read_f32_verified(&latest_dir, latest, &format!("dense.{fname}.v.bin"), n)?;
        }
        state.step = latest.step;
        // the tables changed wholesale behind the optimizer's back: the
        // next snapshot publish must be a full capture, not a delta
        state.dirty.invalidate();
        Ok(latest.gen)
    }

    /// Build a serve-ready [`ModelSnapshot`] whose embedding tables are
    /// windows into a read-only memory mapping of the newest committed
    /// generation's serve-layout files — clean pages are never copied onto
    /// the heap, and every snapshot (and process) mapping the same
    /// generation shares one set of physical pages through the kernel page
    /// cache.
    ///
    /// The chain's base generation must have been written with
    /// [`CheckpointConfig::serve_layout`]; otherwise this returns
    /// [`CkptError::Incompatible`] so callers can fall back to the heap
    /// path ([`CheckpointStore::load_latest`] + [`ModelSnapshot::capture`]).
    /// Rows the delta chain journals on top of the base are patched onto
    /// heap pages (weights only — a snapshot carries no moments), so the
    /// result is bitwise identical to a capture of the recovered state;
    /// `mmap_parity` pins that, including after a kill-and-recover restart.
    ///
    /// `state` is the identity/shape template (exactly what `load_latest`
    /// checks against) and supplies the dense parameter directory; it is
    /// not mutated. Both serve files are CRC-verified *through the
    /// mapping* before anything serves off them — a torn or bit-flipped
    /// generation is a typed refusal, not a bad answer.
    pub fn load_snapshot_mapped(
        &self,
        state: &ModelState,
        fusion: Option<&str>,
    ) -> Result<(u64, ModelSnapshot), CkptError> {
        let chain = resolve_chain(&self.root)?;
        let latest = chain.last().expect("resolve_chain never returns empty");
        check_compatible(latest, state)?;
        let base = &chain[0];
        let n_shards = base.serve_shards.ok_or_else(|| CkptError::Incompatible {
            reason: format!(
                "generation {} has no serve layout (written without \
                 CheckpointConfig::serve_layout) — fall back to the heap path",
                base.gen
            ),
        })?;
        if n_shards == 0 {
            return Err(mf_err(base.gen, "serve_shards must be >= 1"));
        }
        let align = base.serve_align.unwrap_or(SERVE_ALIGN);
        if align == 0 || align % 4 != 0 {
            return Err(mf_err(base.gen, format!("bad serve_align {align}")));
        }
        let base_dir = self.root.join(gen_dir_name(base.gen));
        let layout = ShardLayout::new(n_shards);

        let mut builders = Vec::with_capacity(2);
        for (tag, rows, dim) in
            [("ent", base.ent_rows, base.ent_dim), ("rel", base.rel_rows, base.rel_dim)]
        {
            let name = format!("{tag}.serve.bin");
            let meta = *base
                .files
                .get(&name)
                .ok_or_else(|| mf_err(base.gen, format!("missing file entry for {name}")))?;
            if meta.bytes != serve_layout_bytes(rows, dim, n_shards, align) {
                return Err(mf_err(
                    base.gen,
                    format!("{name}: size does not match its declared serve layout"),
                ));
            }
            let path = base_dir.join(&name);
            let map = TableMap::open(&path).map_err(|e| io_err("mapping", &path, e))?;
            if map.file_bytes() as u64 != meta.bytes {
                return Err(CkptError::LengthMismatch {
                    file: path,
                    expected_bytes: meta.bytes,
                    actual_bytes: map.file_bytes() as u64,
                });
            }
            let mut crc = Crc32::new();
            map.bytes().for_each_chunk(|c| crc.update(c));
            let actual = crc.finish();
            if actual != meta.crc {
                return Err(CkptError::ChecksumMismatch { file: path, expected: meta.crc, actual });
            }

            let map = Arc::new(map);
            let mut pages: Vec<Vec<PageSource>> = Vec::with_capacity(n_shards);
            let mut section_off = 0usize; // float offset of the shard section
            for s in 0..n_shards {
                let shard_rows = layout.shard_rows(rows, s);
                let mut shard_pages = Vec::with_capacity(shard_rows.div_ceil(PAGE_ROWS));
                let mut local = 0;
                while local < shard_rows {
                    let count = (shard_rows - local).min(PAGE_ROWS);
                    shard_pages.push(PageSource::mapped(
                        Arc::clone(&map),
                        section_off + local * dim,
                        count * dim,
                    ));
                    local += count;
                }
                pages.push(shard_pages);
                section_off += (shard_rows * dim * 4).next_multiple_of(align) / 4;
            }
            builders.push(ShardedTableBuilder::from_sources(rows, dim, n_shards, pages));
        }
        let mut it = builders.into_iter();
        let (mut ent_b, mut rel_b) = (it.next().unwrap(), it.next().unwrap());

        // replay the delta chain's journaled rows on top (weights only)
        for m in &chain[1..] {
            let dir = self.root.join(gen_dir_name(m.gen));
            for (tag, b, rows, dim) in [
                ("ent", &mut ent_b, base.ent_rows, base.ent_dim),
                ("rel", &mut rel_b, base.rel_rows, base.rel_dim),
            ] {
                let pages_name = format!("{tag}.pages.bin");
                if !m.files.contains_key(&pages_name) {
                    continue;
                }
                let pages = read_u32_verified(&dir, m, &pages_name)?;
                if !pages.windows(2).all(|w| w[0] < w[1]) {
                    return Err(mf_err(m.gen, format!("{pages_name}: unsorted pages")));
                }
                let n: usize = pages
                    .iter()
                    .map(|&p| {
                        let start = p as usize * PAGE_ROWS;
                        (start + PAGE_ROWS).min(rows).saturating_sub(start) * dim
                    })
                    .sum();
                let data = read_f32_verified(&dir, m, &format!("{tag}.delta.data.bin"), n)?;
                let mut off = 0usize;
                for &p in &pages {
                    let start = p as usize * PAGE_ROWS;
                    if start >= rows {
                        return Err(mf_err(m.gen, format!("page {p} out of range for {rows} rows")));
                    }
                    for id in start..(start + PAGE_ROWS).min(rows) {
                        b.patch_row(id as u32, &data[off..off + dim]);
                        off += dim;
                    }
                }
            }
        }

        // dense params are written whole every generation: latest wins
        let latest_dir = self.root.join(gen_dir_name(latest.gen));
        let mut dense = Vec::with_capacity(state.dense.len());
        for (name, p) in &state.dense {
            let fname = name.replace('.', "_");
            dense.push(read_f32_verified(
                &latest_dir,
                latest,
                &format!("dense.{fname}.data.bin"),
                p.data.len(),
            )?);
        }

        let statics = SnapshotStatics {
            model: state.model.clone(),
            ent_dim: state.ent_dim,
            rel_dim: state.rel_dim,
            repr_dim: state.repr_dim,
            dense_keys: state.dense.keys().cloned().collect(),
            dense_shapes: state.dense.values().map(|p| p.shape.clone()).collect(),
            fusion: fusion.map(str::to_string),
        };
        let snap =
            ModelSnapshot::from_parts(statics, ent_b.build(), rel_b.build(), dense, latest.step);
        Ok((latest.gen, snap))
    }

    /// Committed generation ids, oldest first (manifests not validated).
    pub fn generations(&self) -> Vec<u64> {
        scan_gens(&self.root)
    }
}

/// Sorted unique page indices covering `pending` (rows outside the table
/// are ignored defensively — they cannot arise from optimizer grads).
fn dirty_pages(pending: &HashSet<u32>, rows: usize) -> Vec<u32> {
    let set: BTreeSet<u32> = pending
        .iter()
        .filter(|&&id| (id as usize) < rows)
        .map(|&id| id / PAGE_ROWS as u32)
        .collect();
    set.into_iter().collect()
}

fn apply_page_patch(
    t: &mut EmbeddingTable,
    pages: &[u32],
    data: &[f32],
    m: &[f32],
    v: &[f32],
    gen: u64,
) -> Result<(), CkptError> {
    let dim = t.dim;
    let mut off = 0usize;
    for &p in pages {
        let start = p as usize * PAGE_ROWS;
        if start >= t.rows {
            return Err(mf_err(gen, format!("page {p} out of range for {} rows", t.rows)));
        }
        let end = (start + PAGE_ROWS).min(t.rows);
        let n = (end - start) * dim;
        if off + n > data.len() {
            return Err(mf_err(gen, "delta payload shorter than its page list"));
        }
        t.data[start * dim..end * dim].copy_from_slice(&data[off..off + n]);
        t.m[start * dim..end * dim].copy_from_slice(&m[off..off + n]);
        t.v[start * dim..end * dim].copy_from_slice(&v[off..off + n]);
        off += n;
    }
    if off != data.len() {
        return Err(mf_err(gen, "delta payload longer than its page list"));
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// metrics
// ---------------------------------------------------------------------------

/// Checkpoint payload-size histogram bounds, bytes (log-spaced ×4).
pub const CKPT_BYTES_BOUNDS: [f64; 10] = [
    4096.0,
    16384.0,
    65536.0,
    262144.0,
    1048576.0,
    4194304.0,
    16777216.0,
    67108864.0,
    268435456.0,
    1073741824.0,
];

/// Checkpoint observability, reusing the serve tier's atomic primitives
/// (recording is lock-free; rendering allocates on scrape only). Families:
/// `ngdb_train_checkpoint_{saves,failures,retries}_total{kind="full"|"delta"}`
/// plus payload-bytes and save-duration histograms.
#[derive(Debug)]
pub struct CheckpointMetrics {
    pub saves_full: Counter,
    pub saves_delta: Counter,
    /// saves that failed permanently (retries exhausted)
    pub failures_full: Counter,
    pub failures_delta: Counter,
    /// retry attempts after a transient save error
    pub retries_full: Counter,
    pub retries_delta: Counter,
    /// payload bytes per committed save
    pub save_bytes: Histogram,
    /// wall time per committed save, seconds (includes retries/backoff)
    pub save_seconds: Histogram,
}

impl Default for CheckpointMetrics {
    fn default() -> Self {
        Self::new()
    }
}

impl CheckpointMetrics {
    pub fn new() -> CheckpointMetrics {
        CheckpointMetrics {
            saves_full: Counter::default(),
            saves_delta: Counter::default(),
            failures_full: Counter::default(),
            failures_delta: Counter::default(),
            retries_full: Counter::default(),
            retries_delta: Counter::default(),
            save_bytes: Histogram::new(&CKPT_BYTES_BOUNDS),
            save_seconds: Histogram::new(&LATENCY_BOUNDS),
        }
    }

    /// Render in Prometheus text exposition format (validated by
    /// `scripts/prom_parse.py`, sampled in
    /// `benches/baselines/serve_metrics_sample.prom`).
    pub fn render_prometheus(&self) -> String {
        let mut out = String::with_capacity(2048);
        kind_counter(
            &mut out,
            "ngdb_train_checkpoint_saves_total",
            "Checkpoint generations committed, by kind (full base or delta journal).",
            self.saves_full.get(),
            self.saves_delta.get(),
        );
        kind_counter(
            &mut out,
            "ngdb_train_checkpoint_failures_total",
            "Checkpoint saves that failed permanently after retries (training continues).",
            self.failures_full.get(),
            self.failures_delta.get(),
        );
        kind_counter(
            &mut out,
            "ngdb_train_checkpoint_retries_total",
            "Checkpoint save retry attempts after transient I/O errors.",
            self.retries_full.get(),
            self.retries_delta.get(),
        );
        render_histogram(
            &mut out,
            "ngdb_train_checkpoint_save_bytes",
            "Payload bytes per committed checkpoint save.",
            &self.save_bytes,
        );
        render_histogram(
            &mut out,
            "ngdb_train_checkpoint_save_seconds",
            "Wall time per committed checkpoint save (including retries), seconds.",
            &self.save_seconds,
        );
        out
    }
}

fn kind_counter(out: &mut String, name: &str, help: &str, full: u64, delta: u64) {
    out.push_str(&format!(
        "# HELP {name} {help}\n# TYPE {name} counter\n\
         {name}{{kind=\"full\"}} {full}\n{name}{{kind=\"delta\"}} {delta}\n"
    ));
}

// ---------------------------------------------------------------------------
// trainer-side auto checkpointing
// ---------------------------------------------------------------------------

/// Cadence + retry policy of an [`AutoCheckpointer`].
#[derive(Debug, Clone)]
pub struct CheckpointPolicy {
    /// save whenever `state.step % every_steps == 0` (min 1)
    pub every_steps: u64,
    /// retry attempts after the first failure before giving up on this
    /// save (the pending dirty set is retained either way)
    pub max_retries: u32,
    /// backoff before the first retry; doubles per subsequent retry
    pub retry_backoff: Duration,
}

impl Default for CheckpointPolicy {
    fn default() -> CheckpointPolicy {
        CheckpointPolicy {
            every_steps: 25,
            max_retries: 3,
            retry_backoff: Duration::from_millis(50),
        }
    }
}

/// Outcome of one save attempt cycle (possibly several retries).
#[derive(Debug, Clone)]
pub struct SaveOutcome {
    /// `Some` iff a generation was committed
    pub report: Option<SaveReport>,
    pub retries: u32,
    pub error: Option<String>,
    pub elapsed: Duration,
}

impl SaveOutcome {
    pub fn ok(&self) -> bool {
        self.report.is_some()
    }
}

/// Periodic checkpointing for the training loop: absorbs the optimizer's
/// dirty rows every step, saves on a cadence, retries transient I/O
/// errors with exponential backoff, and **never** propagates a failure —
/// a checkpoint that cannot be written logs, counts into
/// [`CheckpointMetrics`], and leaves training (and the serve tier's
/// published snapshots) untouched.
#[derive(Debug)]
pub struct AutoCheckpointer {
    store: CheckpointStore,
    policy: CheckpointPolicy,
    metrics: Arc<CheckpointMetrics>,
}

impl AutoCheckpointer {
    pub fn new(store: CheckpointStore, policy: CheckpointPolicy) -> AutoCheckpointer {
        AutoCheckpointer { store, policy, metrics: Arc::new(CheckpointMetrics::new()) }
    }

    /// Share a metrics registry (e.g. one scraped alongside
    /// [`crate::serve::ServeMetrics`]).
    pub fn with_metrics(mut self, metrics: Arc<CheckpointMetrics>) -> AutoCheckpointer {
        self.metrics = metrics;
        self
    }

    pub fn metrics(&self) -> Arc<CheckpointMetrics> {
        Arc::clone(&self.metrics)
    }

    pub fn store(&self) -> &CheckpointStore {
        &self.store
    }

    pub fn store_mut(&mut self) -> &mut CheckpointStore {
        &mut self.store
    }

    /// The trainer hook: absorb this step's dirty rows (before the
    /// snapshot publish resets them), then save if the cadence says so.
    /// Returns `None` off-cadence, `Some(outcome)` — never an error —
    /// when a save ran.
    pub fn after_step(&mut self, state: &ModelState) -> Option<SaveOutcome> {
        self.store.absorb_dirty(&state.dirty);
        let every = self.policy.every_steps.max(1);
        if state.step == 0 || state.step % every != 0 {
            return None;
        }
        Some(self.save_now(state))
    }

    /// Save immediately with the retry/backoff policy. Infallible by
    /// design: the failure path is a log line + metrics, not an `Err`.
    pub fn save_now(&mut self, state: &ModelState) -> SaveOutcome {
        let started = Instant::now();
        // eligibility cannot change across retries (anchor and pending
        // are only updated on success), so attribute every retry/failure
        // to the kind the first attempt went for
        let kind = self.store.next_kind(state);
        let mut retries = 0u32;
        loop {
            match self.store.save(state) {
                Ok(report) => {
                    match report.kind {
                        SaveKind::Full => self.metrics.saves_full.inc(),
                        SaveKind::Delta => self.metrics.saves_delta.inc(),
                    }
                    let elapsed = started.elapsed();
                    self.metrics.save_bytes.observe(report.payload_bytes as f64);
                    self.metrics.save_seconds.observe(elapsed.as_secs_f64());
                    return SaveOutcome { report: Some(report), retries, error: None, elapsed };
                }
                Err(e) => {
                    if retries >= self.policy.max_retries {
                        match kind {
                            SaveKind::Full => self.metrics.failures_full.inc(),
                            SaveKind::Delta => self.metrics.failures_delta.inc(),
                        }
                        eprintln!(
                            "checkpoint: save failed after {} attempt(s): {e} — \
                             training continues, dirty rows retained for the next save",
                            retries + 1
                        );
                        return SaveOutcome {
                            report: None,
                            retries,
                            error: Some(e.to_string()),
                            elapsed: started.elapsed(),
                        };
                    }
                    retries += 1;
                    match kind {
                        SaveKind::Full => self.metrics.retries_full.inc(),
                        SaveKind::Delta => self.metrics.retries_delta.inc(),
                    }
                    let backoff = self.policy.retry_backoff * 2u32.pow((retries - 1).min(16));
                    if !backoff.is_zero() {
                        std::thread::sleep(backoff);
                    }
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// legacy one-call API
// ---------------------------------------------------------------------------

/// Save `state` under `dir` as one full generation (created if needed).
/// The legacy convenience wrapper — long-running trainers should hold a
/// [`CheckpointStore`] (or [`AutoCheckpointer`]) for incremental saves.
pub fn save(state: &ModelState, dir: &str) -> Result<()> {
    let mut store = CheckpointStore::open(dir);
    store.save(state)?;
    Ok(())
}

/// Restore the latest committed generation into an already-initialized
/// `state` (shapes must match — init the state from the same
/// manifest/graph first).
pub fn load(state: &mut ModelState, dir: &str) -> Result<()> {
    CheckpointStore::open(dir).load_latest(state)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ParamTensor;
    use crate::runtime::{MockRuntime, Runtime};
    use crate::util::rng::Rng;

    fn tmp(name: &str) -> String {
        let p = std::env::temp_dir().join(format!("ngdb_ckpt_{name}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&p); // stale layouts from prior runs
        p.to_string_lossy().into_owned()
    }

    fn state() -> ModelState {
        let rt = MockRuntime::new();
        ModelState::init(rt.manifest(), "mock", 10, 4, None, 1).unwrap()
    }

    fn assert_bitwise(a: &ModelState, b: &ModelState) {
        // Vec<f32> equality is bitwise for the finite values used here
        assert_eq!(a.step, b.step);
        assert_eq!(a.entities.data, b.entities.data);
        assert_eq!(a.entities.m, b.entities.m);
        assert_eq!(a.entities.v, b.entities.v);
        assert_eq!(a.relations.data, b.relations.data);
        assert_eq!(a.relations.m, b.relations.m);
        assert_eq!(a.relations.v, b.relations.v);
        for (name, pa) in &a.dense {
            let pb = &b.dense[name];
            assert_eq!(pa.data, pb.data);
            assert_eq!(pa.m, pb.m);
            assert_eq!(pa.v, pb.v);
        }
    }

    #[test]
    fn save_load_round_trip_is_bitwise() {
        let dir = tmp("rt");
        let mut a = state();
        a.step = 42;
        let mut rng = Rng::new(7);
        a.entities.data.iter_mut().for_each(|x| *x = rng.uniform_sym(1.0));
        a.entities.m[3] = 0.5;
        a.relations.v[1] = 0.25;
        // the mock model has no dense params; inject one (dotted name —
        // exercises the filename mangling) to cover the dense path
        let dense = ParamTensor {
            shape: vec![2, 3],
            data: (0..6).map(|i| (i as f32) * 0.3 - 1.0).collect(),
            m: vec![0.125; 6],
            v: vec![0.0625; 6],
        };
        a.dense.insert("proj.w".into(), dense);
        save(&a, &dir).unwrap();

        let mut b = state();
        b.dense.insert(
            "proj.w".into(),
            ParamTensor {
                shape: vec![2, 3],
                data: vec![9.0; 6],
                m: vec![9.0; 6],
                v: vec![9.0; 6],
            },
        );
        load(&mut b, &dir).unwrap();
        assert_bitwise(&a, &b);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn incremental_chain_replays_bitwise_vs_the_live_state() {
        let dir = tmp("chain");
        let mut live = state();
        let mut store = CheckpointStore::open(&dir);
        live.step = 1;
        let base = store.save(&live).unwrap();
        assert_eq!(base.kind, SaveKind::Full);

        // three delta saves with scattered row updates (data + moments,
        // both tables)
        for k in 0..3u64 {
            for i in 0..3usize {
                let row = ((k as usize * 13 + i * 7) % live.entities.rows) as u32;
                let dim = live.entities.dim;
                for x in &mut live.entities.data[row as usize * dim..(row as usize + 1) * dim] {
                    *x += 0.25 + k as f32;
                }
                live.entities.m[row as usize * dim] = 0.5 + k as f32;
                live.dirty.ent.insert(row);
            }
            let rrow = (k % live.relations.rows as u64) as u32;
            live.relations.v[rrow as usize * live.relations.dim] = 1.0 + k as f32;
            live.dirty.rel.insert(rrow);
            live.step += 1;
            store.absorb_dirty(&live.dirty);
            live.dirty.reset_to(live.step);
            let r = store.save(&live).unwrap();
            assert_eq!(r.kind, SaveKind::Delta, "save {k} must ride the delta path");
            assert!(
                r.payload_bytes < base.payload_bytes,
                "delta payload {} must undercut the full {}",
                r.payload_bytes,
                base.payload_bytes
            );
        }

        let mut restored = state();
        let gen = CheckpointStore::open(&dir).load_latest(&mut restored).unwrap();
        assert_eq!(gen, 4);
        assert_bitwise(&live, &restored);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn chain_compacts_to_a_full_base_and_gcs_old_generations() {
        let dir = tmp("compact");
        let mut live = state();
        let mut store = CheckpointStore::open(&dir)
            .with_config(CheckpointConfig { max_delta_chain: 2, ..Default::default() });
        let mut kinds = Vec::new();
        for k in 0..6u64 {
            live.step = k + 1;
            live.entities.data[k as usize % 40] += 1.0;
            live.dirty.ent.insert((k % 10) as u32);
            store.absorb_dirty(&live.dirty);
            live.dirty.reset_to(live.step);
            kinds.push(store.save(&live).unwrap().kind);
        }
        assert_eq!(
            kinds,
            [
                SaveKind::Full,  // gen 1: no anchor
                SaveKind::Delta, // gen 2: chain 1
                SaveKind::Delta, // gen 3: chain 2 == max
                SaveKind::Full,  // gen 4: compaction
                SaveKind::Delta,
                SaveKind::Delta,
            ]
        );
        // gen 4's base commit GC'd everything older than the previous
        // base (gen 1 started the previous chain, so nothing yet); a
        // further full commit drops gens 1-3
        store.invalidate_anchor();
        live.step = 7;
        assert_eq!(store.save(&live).unwrap().kind, SaveKind::Full);
        assert_eq!(store.generations(), vec![4, 5, 6, 7]);
        let mut restored = state();
        CheckpointStore::open(&dir).load_latest(&mut restored).unwrap();
        assert_bitwise(&live, &restored);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn empty_delta_generation_is_loadable() {
        let dir = tmp("empty_delta");
        let mut live = state();
        let mut store = CheckpointStore::open(&dir);
        live.step = 1;
        store.save(&live).unwrap();
        live.step = 2; // step moved, no rows dirtied
        let r = store.save(&live).unwrap();
        assert_eq!(r.kind, SaveKind::Delta);
        assert_eq!(r.rows_written, 0);
        let mut restored = state();
        CheckpointStore::open(&dir).load_latest(&mut restored).unwrap();
        assert_bitwise(&live, &restored);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn model_mismatch_rejected() {
        let dir = tmp("mm");
        let a = state();
        save(&a, &dir).unwrap();
        let mut b = state();
        b.model = "gqe".into();
        assert!(load(&mut b, &dir).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn shape_mismatch_rejected() {
        let dir = tmp("sm");
        let a = state();
        save(&a, &dir).unwrap();
        let rt = MockRuntime::new();
        let mut b = ModelState::init(rt.manifest(), "mock", 12, 4, None, 1).unwrap();
        assert!(load(&mut b, &dir).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn relation_and_repr_shape_mismatches_rejected() {
        let dir = tmp("relrepr");
        let a = state();
        save(&a, &dir).unwrap();
        let rt = MockRuntime::new();
        // relation vocab differs (5 vs 4) while the entity table matches
        let mut b = ModelState::init(rt.manifest(), "mock", 10, 5, None, 1).unwrap();
        let err = CheckpointStore::open(&dir).load_latest(&mut b).unwrap_err();
        assert!(matches!(err, CkptError::Incompatible { .. }), "{err}");
        assert!(err.to_string().contains("relation table"), "{err}");
        // repr width differs
        let mut c = state();
        c.repr_dim += 1;
        let err = CheckpointStore::open(&dir).load_latest(&mut c).unwrap_err();
        assert!(err.to_string().contains("repr_dim"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn dense_name_set_mismatch_rejected_both_ways() {
        let dir = tmp("dense_set");
        let mut a = state();
        a.dense.insert(
            "proj.w".into(),
            ParamTensor { shape: vec![2], data: vec![1.0, 2.0], m: vec![0.0; 2], v: vec![0.0; 2] },
        );
        save(&a, &dir).unwrap();
        // checkpoint has a dense param the state lacks: must refuse (the
        // old loader silently ignored it)
        let mut b = state();
        let err = CheckpointStore::open(&dir).load_latest(&mut b).unwrap_err();
        assert!(matches!(err, CkptError::Incompatible { .. }), "{err}");
        assert!(err.to_string().contains("dense param set"), "{err}");
        // state has an extra dense param the checkpoint lacks: also refuse
        let mut c = state();
        c.dense.insert(
            "proj.w".into(),
            ParamTensor { shape: vec![2], data: vec![0.0; 2], m: vec![0.0; 2], v: vec![0.0; 2] },
        );
        c.dense.insert(
            "other.w".into(),
            ParamTensor { shape: vec![2], data: vec![0.0; 2], m: vec![0.0; 2], v: vec![0.0; 2] },
        );
        let err = CheckpointStore::open(&dir).load_latest(&mut c).unwrap_err();
        assert!(matches!(err, CkptError::Incompatible { .. }), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_checkpoint_is_clean_error() {
        let mut s = state();
        let err = CheckpointStore::open("/nonexistent/ckpt").load_latest(&mut s).unwrap_err();
        assert!(matches!(err, CkptError::NoCheckpoint { .. }), "{err}");
        assert!(load(&mut s, "/nonexistent/ckpt").is_err());
    }

    #[test]
    fn stale_staging_dirs_are_swept_on_open() {
        let dir = tmp("sweep");
        let staging = Path::new(&dir).join(".staging.gen-000009");
        std::fs::create_dir_all(&staging).unwrap();
        std::fs::write(staging.join("ent.data.bin"), b"torn").unwrap();
        let _ = CheckpointStore::open(&dir);
        assert!(!staging.exists(), "open must sweep kill -9 wreckage");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn manifest_round_trips_and_detects_corruption() {
        let m = GenManifest {
            gen: 3,
            kind: SaveKind::Delta,
            step: 17,
            model: "mock".into(),
            ent_rows: 10,
            ent_dim: 4,
            rel_rows: 4,
            rel_dim: 4,
            repr_dim: 4,
            dense: vec!["a.w".into(), "b.w".into()],
            parent: 2,
            base: 1,
            chain: 2,
            serve_shards: None,
            serve_align: None,
            files: BTreeMap::from([
                ("ent.pages.bin".to_string(), FileMeta { bytes: 8, crc: 0xDEAD_BEEF }),
                ("ent.delta.data.bin".to_string(), FileMeta { bytes: 128, crc: 7 }),
            ]),
        };
        let content = render_manifest(&m);
        let full = format!("{content}crc=0x{:08X}\n", crc32(content.as_bytes()));
        let back = parse_manifest(&full, 3).unwrap();
        assert_eq!(back.kind, SaveKind::Delta);
        assert_eq!((back.parent, back.base, back.chain), (2, 1, 2));
        assert_eq!(back.dense, m.dense);
        assert_eq!(back.files, m.files);
        assert_eq!((back.serve_shards, back.serve_align), (None, None));
        // the optional serve-layout keys round-trip when present...
        let with_serve = GenManifest {
            kind: SaveKind::Full,
            serve_shards: Some(4),
            serve_align: Some(4096),
            ..m.clone()
        };
        let content = render_manifest(&with_serve);
        let full2 = format!("{content}crc=0x{:08X}\n", crc32(content.as_bytes()));
        let back = parse_manifest(&full2, 3).unwrap();
        assert_eq!((back.serve_shards, back.serve_align), (Some(4), Some(4096)));
        // single-byte corruption anywhere must fail the self-checksum
        let mut corrupt = full.clone().into_bytes();
        corrupt[10] ^= 0x01;
        let err = parse_manifest(std::str::from_utf8(&corrupt).unwrap(), 3).unwrap_err();
        assert!(matches!(err, CkptError::ManifestCorrupt { .. }), "{err}");
        // and a manifest renamed into the wrong generation dir is refused
        assert!(parse_manifest(&full, 4).is_err());
    }

    #[test]
    fn mapped_snapshot_matches_the_recovered_state_bitwise() {
        let dir = tmp("mmap_full");
        let mut live = state();
        live.step = 3;
        let mut rng = Rng::new(11);
        live.entities.data.iter_mut().for_each(|x| *x = rng.uniform_sym(1.0));
        live.relations.data.iter_mut().for_each(|x| *x = rng.uniform_sym(1.0));
        for n in [1usize, 2, 4, 7] {
            let sub = format!("{dir}-{n}");
            let mut store = CheckpointStore::open(&sub)
                .with_config(CheckpointConfig { serve_layout: Some(n), ..Default::default() });
            store.save(&live).unwrap();
            let (gen, snap) =
                CheckpointStore::open(&sub).load_snapshot_mapped(&state(), None).unwrap();
            assert_eq!((gen, snap.step(), snap.n_shards()), (1, 3, n));
            assert_eq!(snap.entities().to_flat(), live.entities.data, "n={n}");
            assert_eq!(snap.relations().to_flat(), live.relations.data, "n={n}");
            assert_eq!(snap.entities().heap_bytes(), 0, "clean base: no heap pages");
            assert_eq!(snap.mapped_bytes(), snap.entities().bytes() + snap.relations().bytes());
            std::fs::remove_dir_all(&sub).ok();
        }
    }

    #[test]
    fn mapped_snapshot_replays_delta_chains_onto_heap_pages() {
        let dir = tmp("mmap_chain");
        let mut live = state();
        let mut store = CheckpointStore::open(&dir)
            .with_config(CheckpointConfig { serve_layout: Some(4), ..Default::default() });
        live.step = 1;
        store.save(&live).unwrap();
        for k in 0..2u64 {
            let row = (k * 3 + 1) as u32;
            let dim = live.entities.dim;
            for x in &mut live.entities.data[row as usize * dim..(row as usize + 1) * dim] {
                *x += 1.5 + k as f32;
            }
            live.dirty.ent.insert(row);
            live.step += 1;
            store.absorb_dirty(&live.dirty);
            live.dirty.reset_to(live.step);
            assert_eq!(store.save(&live).unwrap().kind, SaveKind::Delta);
        }
        let (gen, snap) =
            CheckpointStore::open(&dir).load_snapshot_mapped(&state(), None).unwrap();
        assert_eq!((gen, snap.step()), (3, 3));
        assert_eq!(snap.entities().to_flat(), live.entities.data);
        assert_eq!(snap.relations().to_flat(), live.relations.data);
        // journaled rows materialized on heap; everything else stayed mapped
        assert!(snap.entities().heap_bytes() > 0);
        assert!(snap.mapped_bytes() > 0);
        // the heap loader still recovers the same state with serve files present
        let mut restored = state();
        CheckpointStore::open(&dir).load_latest(&mut restored).unwrap();
        assert_bitwise(&live, &restored);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn mapped_load_without_a_serve_layout_is_a_typed_refusal() {
        let dir = tmp("mmap_none");
        save(&state(), &dir).unwrap();
        let err =
            CheckpointStore::open(&dir).load_snapshot_mapped(&state(), None).unwrap_err();
        assert!(matches!(err, CkptError::Incompatible { .. }), "{err}");
        assert!(err.to_string().contains("serve layout"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn corrupt_serve_file_is_refused_before_serving() {
        let dir = tmp("mmap_corrupt");
        let mut live = state();
        live.step = 1;
        let mut store = CheckpointStore::open(&dir)
            .with_config(CheckpointConfig { serve_layout: Some(2), ..Default::default() });
        store.save(&live).unwrap();
        let path = Path::new(&dir).join("gen-000001").join("ent.serve.bin");
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[5] ^= 0x40;
        std::fs::write(&path, &bytes).unwrap();
        let err =
            CheckpointStore::open(&dir).load_snapshot_mapped(&state(), None).unwrap_err();
        assert!(matches!(err, CkptError::ChecksumMismatch { .. }), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn crc32_matches_the_ieee_reference_vector() {
        // the classic check value for the reflected IEEE polynomial
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn metrics_render_as_valid_kind_labelled_families() {
        let m = CheckpointMetrics::new();
        m.saves_full.inc();
        m.saves_delta.add(3);
        m.retries_delta.inc();
        m.save_bytes.observe(100_000.0);
        m.save_seconds.observe(0.01);
        let text = m.render_prometheus();
        for needle in [
            "# TYPE ngdb_train_checkpoint_saves_total counter",
            "ngdb_train_checkpoint_saves_total{kind=\"full\"} 1",
            "ngdb_train_checkpoint_saves_total{kind=\"delta\"} 3",
            "ngdb_train_checkpoint_failures_total{kind=\"full\"} 0",
            "ngdb_train_checkpoint_retries_total{kind=\"delta\"} 1",
            "# TYPE ngdb_train_checkpoint_save_bytes histogram",
            "ngdb_train_checkpoint_save_bytes_bucket{le=\"+Inf\"} 1",
            "ngdb_train_checkpoint_save_seconds_count 1",
        ] {
            assert!(text.contains(needle), "missing {needle:?} in:\n{text}");
        }
    }
}
