//! Checkpointing: save/restore full trainable state (embedding tables with
//! Adam moments + dense params) so long runs survive restarts and trained
//! models can be served/evaluated later.
//!
//! Format: a directory with a small text header (`meta.txt`: model, dims,
//! step) and one raw little-endian f32 file per tensor — deliberately the
//! same trivial encoding `aot.py` uses for initial params, so checkpoints
//! are toolable with numpy one-liners.

use anyhow::{bail, Context, Result};

use crate::model::state::{read_f32_file, ModelState};

fn write_f32(path: &str, data: &[f32]) -> Result<()> {
    let bytes: Vec<u8> = data.iter().flat_map(|x| x.to_le_bytes()).collect();
    std::fs::write(path, bytes).with_context(|| format!("writing {path}"))
}

/// Save `state` under `dir` (created if needed; overwrites).
pub fn save(state: &ModelState, dir: &str) -> Result<()> {
    std::fs::create_dir_all(dir)?;
    let meta = format!(
        "model={}\nstep={}\nent_rows={}\nent_dim={}\nrel_rows={}\nrel_dim={}\n\
         repr_dim={}\ndense={}\n",
        state.model,
        state.step,
        state.entities.rows,
        state.entities.dim,
        state.relations.rows,
        state.relations.dim,
        state.repr_dim,
        state.dense.keys().cloned().collect::<Vec<_>>().join(","),
    );
    std::fs::write(format!("{dir}/meta.txt"), meta)?;
    for (tag, t) in [("ent", &state.entities), ("rel", &state.relations)] {
        write_f32(&format!("{dir}/{tag}.data.bin"), &t.data)?;
        write_f32(&format!("{dir}/{tag}.m.bin"), &t.m)?;
        write_f32(&format!("{dir}/{tag}.v.bin"), &t.v)?;
    }
    for (name, p) in &state.dense {
        let fname = name.replace('.', "_");
        write_f32(&format!("{dir}/dense.{fname}.data.bin"), &p.data)?;
        write_f32(&format!("{dir}/dense.{fname}.m.bin"), &p.m)?;
        write_f32(&format!("{dir}/dense.{fname}.v.bin"), &p.v)?;
    }
    Ok(())
}

/// Restore a checkpoint into an already-initialized `state` (shapes must
/// match — init the state from the same manifest/graph first).
pub fn load(state: &mut ModelState, dir: &str) -> Result<()> {
    let meta = std::fs::read_to_string(format!("{dir}/meta.txt"))
        .with_context(|| format!("no checkpoint at {dir}"))?;
    let field = |key: &str| -> Result<String> {
        meta.lines()
            .find_map(|l| l.strip_prefix(&format!("{key}=")))
            .map(str::to_string)
            .ok_or_else(|| anyhow::anyhow!("checkpoint meta missing {key}"))
    };
    if field("model")? != state.model {
        bail!("checkpoint is for model {:?}, state is {:?}", field("model")?, state.model);
    }
    let ent_rows: usize = field("ent_rows")?.parse()?;
    let ent_dim: usize = field("ent_dim")?.parse()?;
    if ent_rows != state.entities.rows || ent_dim != state.entities.dim {
        bail!(
            "entity table shape mismatch: checkpoint {}x{}, state {}x{}",
            ent_rows, ent_dim, state.entities.rows, state.entities.dim
        );
    }
    state.step = field("step")?.parse()?;
    for (tag, t) in [("ent", &mut state.entities), ("rel", &mut state.relations)] {
        let n = t.data.len();
        t.data = read_f32_file(&format!("{dir}/{tag}.data.bin"), n)?;
        t.m = read_f32_file(&format!("{dir}/{tag}.m.bin"), n)?;
        t.v = read_f32_file(&format!("{dir}/{tag}.v.bin"), n)?;
    }
    for (name, p) in &mut state.dense {
        let fname = name.replace('.', "_");
        let n = p.data.len();
        p.data = read_f32_file(&format!("{dir}/dense.{fname}.data.bin"), n)?;
        p.m = read_f32_file(&format!("{dir}/dense.{fname}.m.bin"), n)?;
        p.v = read_f32_file(&format!("{dir}/dense.{fname}.v.bin"), n)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{MockRuntime, Runtime};
    use crate::util::rng::Rng;

    fn tmp(name: &str) -> String {
        std::env::temp_dir().join(format!("ngdb_ckpt_{name}")).to_string_lossy().into_owned()
    }

    fn state() -> ModelState {
        let rt = MockRuntime::new();
        ModelState::init(rt.manifest(), "mock", 10, 4, None, 1).unwrap()
    }

    #[test]
    fn save_load_round_trip() {
        let dir = tmp("rt");
        let mut a = state();
        a.step = 42;
        let mut rng = Rng::new(7);
        a.entities.data.iter_mut().for_each(|x| *x = rng.uniform_sym(1.0));
        a.entities.m[3] = 0.5;
        save(&a, &dir).unwrap();

        let mut b = state();
        load(&mut b, &dir).unwrap();
        assert_eq!(b.step, 42);
        assert_eq!(a.entities.data, b.entities.data);
        assert_eq!(a.entities.m, b.entities.m);
        assert_eq!(a.relations.v, b.relations.v);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn model_mismatch_rejected() {
        let dir = tmp("mm");
        let a = state();
        save(&a, &dir).unwrap();
        let mut b = state();
        b.model = "gqe".into();
        assert!(load(&mut b, &dir).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn shape_mismatch_rejected() {
        let dir = tmp("sm");
        let a = state();
        save(&a, &dir).unwrap();
        let rt = MockRuntime::new();
        let mut b = ModelState::init(rt.manifest(), "mock", 12, 4, None, 1).unwrap();
        assert!(load(&mut b, &dir).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_checkpoint_is_clean_error() {
        let mut s = state();
        assert!(load(&mut s, "/nonexistent/ckpt").is_err());
    }
}
