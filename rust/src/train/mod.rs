//! Training loops: the operator-level trainer, the query-level and
//! per-query baselines, the multi-worker data-parallel path, and the
//! single-hop (Table 2) trainer — all thin drivers over the shared
//! [`step`] pipeline (sample → build DAGs → execute → reduce → optimize)
//! and its warm per-session execution engine.

pub mod checkpoint;
pub mod multi_worker;
pub mod single_hop;
pub mod step;
pub mod trainer;

pub use checkpoint::{
    AutoCheckpointer, CheckpointConfig, CheckpointMetrics, CheckpointPolicy, CheckpointStore,
    CkptError, SaveKind, SaveOutcome, SaveReport,
};
pub use multi_worker::{modeled_speedup, ring_allreduce_secs, train_multi_worker,
                       MultiWorkerReport};
pub use single_hop::{train_complex, SingleHopReport};
pub use step::{DagPrefetcher, ExecStats, StepOutcome, StepPipeline};
pub use trainer::{TrainReport, Trainer};
