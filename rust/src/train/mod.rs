//! Training loops: the operator-level trainer, the query-level and
//! per-query baselines, the multi-worker data-parallel path, and the
//! single-hop (Table 2) trainer.

pub mod checkpoint;
pub mod multi_worker;
pub mod single_hop;
pub mod trainer;

pub use multi_worker::{modeled_speedup, ring_allreduce_secs, train_multi_worker,
                       MultiWorkerReport};
pub use single_hop::{train_complex, SingleHopReport};
pub use trainer::{TrainReport, Trainer};
