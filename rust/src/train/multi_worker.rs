//! Data-parallel multi-worker training (Fig. 7 / Table 2 multi-GPU).
//!
//! W workers each sample and execute their shard of every global batch,
//! then all-reduce gradients and apply one optimizer step. On this one-core
//! testbed the workers are OS threads sharing the PJRT CPU client, so
//! *measured* wall-clock cannot scale; correctness (worker-count-invariant
//! gradients) is tested, and the Fig. 7 harness combines the measured
//! single-worker compute time with the measured all-reduce volume in an
//! explicit ring-allreduce cost model (DESIGN.md §Substitutions).

use std::sync::{Arc, Mutex};

use anyhow::Result;

use crate::config::ExperimentConfig;
use crate::exec::{Engine, EngineConfig, Grads};
use crate::kg::KgStore;
use crate::model::ModelState;
use crate::query::QueryDag;
use crate::runtime::Runtime;
use crate::sampler::{ground, negatives, GroundedQuery};
use crate::util::rng::Rng;

/// Report of a multi-worker run.
#[derive(Debug, Clone, Default)]
pub struct MultiWorkerReport {
    pub steps: usize,
    pub workers: usize,
    pub qps: f64,
    /// bytes all-reduced per step (gradient traffic)
    pub allreduce_bytes_per_step: usize,
    /// mean per-worker execute seconds per step
    pub worker_exec_secs: f64,
    pub loss_curve: Vec<f64>,
}

/// Ring all-reduce cost model: each of W workers sends and receives
/// `2 (W-1)/W · bytes` over links of `bw` bytes/sec with `lat` secs/hop.
pub fn ring_allreduce_secs(bytes: usize, workers: usize, bw: f64, lat: f64) -> f64 {
    if workers <= 1 {
        return 0.0;
    }
    let w = workers as f64;
    2.0 * (w - 1.0) / w * bytes as f64 / bw + 2.0 * (w - 1.0) * lat
}

/// Modeled speedup for Fig. 7: compute shards perfectly, comm per the ring
/// model overlapped not at all (pessimistic).
pub fn modeled_speedup(t_compute_1: f64, grad_bytes: usize, workers: usize,
                       bw: f64, lat: f64) -> f64 {
    let t_w = t_compute_1 / workers as f64
        + ring_allreduce_secs(grad_bytes, workers, bw, lat);
    t_compute_1 / t_w
}

/// Train with `cfg.workers` data-parallel workers.
pub fn train_multi_worker(
    rt: &dyn Runtime,
    kg: Arc<KgStore>,
    cfg: &ExperimentConfig,
    state: &mut ModelState,
) -> Result<MultiWorkerReport> {
    let workers = cfg.workers.max(1);
    let n_neg = rt.manifest().dims.n_neg;
    let supports_neg = crate::config::model_supports_negation(&state.model);
    let adam = crate::optim::AdamConfig { lr: cfg.lr as f32, ..Default::default() };
    let mut report = MultiWorkerReport {
        workers,
        steps: cfg.steps,
        ..Default::default()
    };
    let shard = cfg.batch_queries.div_ceil(workers);
    let t0 = std::time::Instant::now();
    let mut exec_secs_total = 0.0f64;

    for step in 0..cfg.steps {
        // merged gradient accumulator + per-worker wall clocks
        let merged: Mutex<Grads> = Mutex::new(Grads::default());
        let exec_secs: Mutex<Vec<f64>> = Mutex::new(vec![0.0; workers]);
        let state_ref: &ModelState = state;

        std::thread::scope(|scope| -> Result<()> {
            let mut handles = Vec::new();
            for w in 0..workers {
                let kg = Arc::clone(&kg);
                let merged = &merged;
                let exec_secs = &exec_secs;
                let patterns = cfg.patterns.clone();
                handles.push(scope.spawn(move || -> Result<()> {
                    let mut rng =
                        Rng::new(cfg.seed ^ ((step as u64) << 8) ^ w as u64);
                    // sample this worker's shard
                    let mut batch: Vec<GroundedQuery> = Vec::with_capacity(shard);
                    let mut guard = 0;
                    while batch.len() < shard && guard < shard * 30 {
                        guard += 1;
                        let p = *rng.choice(&patterns);
                        if let Some(mut q) = ground(&kg, &mut rng, p) {
                            q.negatives = negatives(&kg, &mut rng, q.answer, None, n_neg);
                            batch.push(q);
                        }
                    }
                    let mut dag = QueryDag::default();
                    for q in &batch {
                        dag.add_query(&q.tree, q.answer, q.negatives.clone(),
                            q.pattern.name(), supports_neg)?;
                    }
                    dag.add_gradient_nodes();
                    let engine = Engine::new(rt, EngineConfig::default());
                    let mut grads = Grads::default();
                    let sw = std::time::Instant::now();
                    engine.run(&dag, state_ref, &mut grads)?;
                    exec_secs.lock().unwrap()[w] = sw.elapsed().as_secs_f64();
                    // all-reduce contribution (shared-memory merge)
                    let mut m = merged.lock().unwrap();
                    m.loss += grads.loss;
                    m.n_queries += grads.n_queries;
                    for (k, v) in grads.ent {
                        let e = m.ent.entry(k).or_insert_with(|| vec![0.0; v.len()]);
                        for (a, b) in e.iter_mut().zip(&v) {
                            *a += b;
                        }
                    }
                    for (k, v) in grads.rel {
                        let e = m.rel.entry(k).or_insert_with(|| vec![0.0; v.len()]);
                        for (a, b) in e.iter_mut().zip(&v) {
                            *a += b;
                        }
                    }
                    for (k, v) in grads.dense {
                        let e = m.dense.entry(k).or_insert_with(|| vec![0.0; v.len()]);
                        for (a, b) in e.iter_mut().zip(&v) {
                            *a += b;
                        }
                    }
                    Ok(())
                }));
            }
            for h in handles {
                h.join().expect("worker panicked")?;
            }
            Ok(())
        })?;

        let mut grads = merged.into_inner().unwrap();
        // gradient traffic the real system would all-reduce
        let bytes: usize = grads.ent.values().map(|v| v.len() * 4).sum::<usize>()
            + grads.rel.values().map(|v| v.len() * 4).sum::<usize>()
            + grads.dense.values().map(|v| v.len() * 4).sum::<usize>();
        report.allreduce_bytes_per_step = bytes;
        exec_secs_total += crate::util::stats::mean(&exec_secs.into_inner().unwrap());

        grads.normalize();
        report.loss_curve.push(grads.loss / grads.n_queries.max(1) as f64);
        state.step += 1;
        let s = state.step;
        for (name, g) in &grads.dense {
            if let Some(p) = state.dense.get_mut(name) {
                adam.apply_dense(p, g, s);
            }
        }
        adam.apply_sparse(&mut state.entities, &grads.ent, s);
        adam.apply_sparse(&mut state.relations, &grads.rel, s);
    }

    report.qps = (cfg.steps * cfg.batch_queries) as f64 / t0.elapsed().as_secs_f64();
    report.worker_exec_secs = exec_secs_total / cfg.steps.max(1) as f64;
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kg::KgSpec;
    use crate::query::Pattern;
    use crate::runtime::MockRuntime;

    fn cfg(workers: usize) -> ExperimentConfig {
        ExperimentConfig {
            model: "mock".into(),
            steps: 2,
            batch_queries: 8,
            workers,
            patterns: vec![Pattern::P1, Pattern::I2],
            ..Default::default()
        }
    }

    fn kg() -> Arc<KgStore> {
        Arc::new(KgSpec::preset("toy", 1.0).unwrap().generate().unwrap())
    }

    #[test]
    fn multi_worker_runs_and_reports() {
        let rt = MockRuntime::new();
        let kg = kg();
        let mut state = ModelState::init(
            crate::runtime::Runtime::manifest(&rt), "mock",
            kg.n_entities, kg.n_relations, None, 1).unwrap();
        let r = train_multi_worker(&rt, kg, &cfg(4), &mut state).unwrap();
        assert_eq!(r.workers, 4);
        assert!(r.allreduce_bytes_per_step > 0);
        assert!(r.loss_curve.iter().all(|l| l.is_finite()));
    }

    #[test]
    fn worker_count_does_not_change_sampled_gradient_semantics() {
        // same total batch across 1 vs 2 workers won't sample the same
        // queries (independent streams), but state must evolve finitely and
        // deterministically per seed.
        let rt = MockRuntime::new();
        let kg = kg();
        let mk_state = || ModelState::init(
            crate::runtime::Runtime::manifest(&rt), "mock",
            kg.n_entities, kg.n_relations, None, 1).unwrap();
        let mut s1 = mk_state();
        let mut s2 = mk_state();
        let r1 = train_multi_worker(&rt, Arc::clone(&kg), &cfg(2), &mut s1).unwrap();
        let r2 = train_multi_worker(&rt, Arc::clone(&kg), &cfg(2), &mut s2).unwrap();
        assert_eq!(r1.loss_curve, r2.loss_curve, "replay must be deterministic");
        assert_eq!(s1.entities.data, s2.entities.data);
    }

    #[test]
    fn ring_model_monotone() {
        let t1 = 1.0;
        let s2 = modeled_speedup(t1, 1 << 20, 2, 10e9, 5e-6);
        let s4 = modeled_speedup(t1, 1 << 20, 4, 10e9, 5e-6);
        let s8 = modeled_speedup(t1, 1 << 20, 8, 10e9, 5e-6);
        assert!(s2 > 1.5 && s4 > s2 && s8 > s4, "{s2} {s4} {s8}");
        assert_eq!(ring_allreduce_secs(1 << 20, 1, 1e9, 1e-6), 0.0);
    }
}
