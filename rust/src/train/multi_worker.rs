//! Data-parallel multi-worker training (Fig. 7 / Table 2 multi-GPU).
//!
//! W workers each execute their shard of every global batch through the
//! shared [`step::StepPipeline`] (sample → build → execute happen per
//! worker; reduce → optimize on the driver), then gradients all-reduce
//! **deterministically in worker order** via
//! [`crate::exec::Grads::accumulate`] and one optimizer step applies.
//! Per-worker [`EngineSession`]s persist across steps — one warm gather
//! worker per training worker for the whole run, no per-step (let alone
//! per-run) thread spawning inside the engine.
//!
//! Shards come from the shared async [`SamplerStream`] via exact-size
//! sharded receives (`Pipelining::Async`, the default: one stream feeds
//! all workers, no per-worker sampling code), or — `Pipelining::Sync` —
//! from per-worker/per-step [`Rng::fork`] streams (forking by step, then
//! by worker, is collision-free by construction; the previous
//! `seed ^ (step << 8) ^ w` scheme collided worker 256 at step 0 with
//! worker 0 at step 1).
//!
//! On this one-core testbed the workers are OS threads sharing the PJRT
//! CPU client, so *measured* wall-clock cannot scale; correctness
//! (worker-count-invariant gradients) is tested, and the Fig. 7 harness
//! combines the measured single-worker compute time with the measured
//! all-reduce volume in an explicit ring-allreduce cost model (DESIGN.md
//! §Substitutions). [`MultiWorkerReport::phases`] attributes where each
//! step's wall-clock goes (worker-parallel phases as per-worker means).

use std::sync::Arc;
use std::time::Instant;

use anyhow::{bail, Result};

use super::step::{self, ExecStats, StepPipeline};
use crate::config::{Batching, ExperimentConfig, Pipelining};
use crate::exec::{EngineConfig, EngineSession, Grads};
use crate::kg::KgStore;
use crate::model::ModelState;
use crate::optim::AdamConfig;
use crate::runtime::Runtime;
use crate::sampler::{GroundedQuery, SamplerStream};
use crate::util::rng::Rng;
use crate::util::timer::PhaseTimer;

/// Report of a multi-worker run.
#[derive(Debug, Clone, Default)]
pub struct MultiWorkerReport {
    pub steps: usize,
    pub workers: usize,
    pub qps: f64,
    /// bytes all-reduced per step (gradient traffic)
    pub allreduce_bytes_per_step: usize,
    /// mean per-worker execute seconds per step
    pub worker_exec_secs: f64,
    pub loss_curve: Vec<f64>,
    /// phase attribution of the run's wall clock, same vocabulary as
    /// [`super::TrainReport::phases`] plus `allreduce`; worker-parallel
    /// phases (`build_dag`, `execute` and its sub-buckets) are per-worker
    /// means so they stay comparable to step wall-clock
    pub phases: Vec<(String, f64)>,
}

/// Ring all-reduce cost model: each of W workers sends and receives
/// `2 (W-1)/W · bytes` over links of `bw` bytes/sec with `lat` secs/hop.
pub fn ring_allreduce_secs(bytes: usize, workers: usize, bw: f64, lat: f64) -> f64 {
    if workers <= 1 {
        return 0.0;
    }
    let w = workers as f64;
    2.0 * (w - 1.0) / w * bytes as f64 / bw + 2.0 * (w - 1.0) * lat
}

/// Modeled speedup for Fig. 7: compute shards perfectly, comm per the ring
/// model overlapped not at all (pessimistic).
pub fn modeled_speedup(t_compute_1: f64, grad_bytes: usize, workers: usize,
                       bw: f64, lat: f64) -> f64 {
    let t_w = t_compute_1 / workers as f64
        + ring_allreduce_secs(grad_bytes, workers, bw, lat);
    t_compute_1 / t_w
}

/// Train with `cfg.workers` data-parallel workers.
pub fn train_multi_worker(
    rt: &dyn Runtime,
    kg: Arc<KgStore>,
    cfg: &ExperimentConfig,
    state: &mut ModelState,
) -> Result<MultiWorkerReport> {
    let workers = cfg.workers.max(1);
    let n_neg = rt.manifest().dims.n_neg;
    let supports_neg = crate::config::model_supports_negation(&state.model);
    let adam = AdamConfig { lr: cfg.lr as f32, ..Default::default() };
    let mut report = MultiWorkerReport {
        workers,
        steps: cfg.steps,
        ..Default::default()
    };
    let shard = cfg.batch_queries.div_ceil(workers);
    let mut phases = PhaseTimer::default();

    // Per-worker step pipelines persist across every step: one warm engine
    // session (and gather worker) per training worker for the whole run.
    // Each worker fuses its shard operator-level.
    let mut pipelines: Vec<StepPipeline<'_>> = (0..workers)
        .map(|_| {
            StepPipeline::new(
                EngineSession::new(rt, EngineConfig::default()),
                adam,
                Batching::OperatorLevel,
                supports_neg,
            )
        })
        .collect();

    // Query feed: one shared producer stream sharded across workers, or
    // deterministic per-worker/per-step forked sync streams.
    let stream = match cfg.pipelining {
        Pipelining::Async => {
            Some(SamplerStream::spawn(Arc::clone(&kg), cfg.sampler(n_neg)))
        }
        Pipelining::Sync => None,
    };
    let mut root_rng = Rng::new(cfg.seed);

    let t0 = Instant::now();
    let mut exec_secs_total = 0.0f64;
    for step in 0..cfg.steps {
        // ---- sample: one shard per worker, received in worker order ------
        let shards: Vec<Vec<GroundedQuery>> = phases.time("sample", || match &stream {
            Some(s) => (0..workers).map(|_| s.recv_exact(shard)).collect(),
            None => {
                let mut step_rng = root_rng.fork(step as u64);
                (0..workers)
                    .map(|w| {
                        let mut rng = step_rng.fork(w as u64);
                        step::sample_sync(&kg, &mut rng, &cfg.patterns, shard, n_neg)
                    })
                    .collect()
            }
        });
        if shards.iter().all(|s| s.is_empty()) {
            bail!("sampler produced no queries for the multi-worker step");
        }

        // ---- build + execute: every worker drives the shared pipeline
        //      over its shard, on its own warm session ----------------------
        let state_ref: &ModelState = state;
        let mut results: Vec<Option<Result<(Grads, ExecStats)>>> =
            (0..workers).map(|_| None).collect();
        std::thread::scope(|scope| {
            for (pipeline, (shard_batch, slot)) in
                pipelines.iter_mut().zip(shards.into_iter().zip(results.iter_mut()))
            {
                scope.spawn(move || {
                    let mut grads = Grads::default();
                    let r = pipeline.run_batch(&shard_batch, state_ref, &mut grads);
                    *slot = Some(r.map(|exec| (grads, exec)));
                });
            }
        });

        // ---- all-reduce: fold worker contributions in worker order (the
        //      shared-memory stand-in; float addition order is pinned so
        //      replays are bit-identical) ---------------------------------
        let t_reduce = Instant::now();
        let mut grads = Grads::default();
        let mut exec = ExecStats::default();
        for r in results {
            let (g, e) = r.expect("worker did not run")?;
            grads.accumulate(g);
            exec.merge(e);
        }
        phases.add("allreduce", t_reduce.elapsed().as_secs_f64());
        let wf = workers as f64;
        phases.add("build_dag", exec.build_secs / wf);
        phases.add("execute", exec.execute_wall_secs / wf);
        exec.attribute_execute(&mut phases, 1.0 / wf);
        exec_secs_total += exec.execute_wall_secs / wf;

        // gradient traffic the real system would all-reduce
        let bytes: usize = grads.ent.values().map(|v| v.len() * 4).sum::<usize>()
            + grads.rel.values().map(|v| v.len() * 4).sum::<usize>()
            + grads.dense.values().map(|v| v.len() * 4).sum::<usize>();
        report.allreduce_bytes_per_step = bytes;

        // ---- reduce + optimize (shared pipeline tail) --------------------
        grads.normalize();
        report.loss_curve.push(grads.loss / grads.n_queries.max(1) as f64);
        phases.time("optimize", || step::optimize(state, &grads, &adam));
    }

    if let Some(s) = stream {
        s.shutdown();
    }
    report.qps = (cfg.steps * cfg.batch_queries) as f64 / t0.elapsed().as_secs_f64();
    report.worker_exec_secs = exec_secs_total / cfg.steps.max(1) as f64;
    report.phases = phases.buckets.clone();
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kg::KgSpec;
    use crate::query::Pattern;
    use crate::runtime::MockRuntime;

    fn cfg(workers: usize) -> ExperimentConfig {
        ExperimentConfig {
            model: "mock".into(),
            steps: 2,
            batch_queries: 8,
            workers,
            patterns: vec![Pattern::P1, Pattern::I2],
            ..Default::default()
        }
    }

    fn kg() -> Arc<KgStore> {
        Arc::new(KgSpec::preset("toy", 1.0).unwrap().generate().unwrap())
    }

    fn mk_state(rt: &MockRuntime, kg: &KgStore) -> ModelState {
        ModelState::init(
            crate::runtime::Runtime::manifest(rt), "mock",
            kg.n_entities, kg.n_relations, None, 1).unwrap()
    }

    #[test]
    fn multi_worker_runs_and_reports() {
        let rt = MockRuntime::new();
        let kg = kg();
        let mut state = mk_state(&rt, &kg);
        let r = train_multi_worker(&rt, kg, &cfg(4), &mut state).unwrap();
        assert_eq!(r.workers, 4);
        assert!(r.allreduce_bytes_per_step > 0);
        assert!(r.loss_curve.iter().all(|l| l.is_finite()));
    }

    #[test]
    fn worker_count_does_not_change_sampled_gradient_semantics() {
        // same total batch across 1 vs 2 workers won't sample the same
        // queries (independent shards), but state must evolve finitely and
        // deterministically per seed.
        let rt = MockRuntime::new();
        let kg = kg();
        let mut s1 = mk_state(&rt, &kg);
        let mut s2 = mk_state(&rt, &kg);
        let r1 = train_multi_worker(&rt, Arc::clone(&kg), &cfg(2), &mut s1).unwrap();
        let r2 = train_multi_worker(&rt, Arc::clone(&kg), &cfg(2), &mut s2).unwrap();
        assert_eq!(r1.loss_curve, r2.loss_curve, "replay must be deterministic");
        assert_eq!(s1.entities.data, s2.entities.data);
    }

    #[test]
    fn sync_pipelining_forks_deterministic_worker_streams() {
        // the Rng::fork(step) -> fork(worker) derivation must replay
        // bit-identically (and, unlike the old xor scheme, cannot collide
        // across (step, worker) pairs)
        let rt = MockRuntime::new();
        let kg = kg();
        let mut c = cfg(3);
        c.pipelining = Pipelining::Sync;
        let mut s1 = mk_state(&rt, &kg);
        let mut s2 = mk_state(&rt, &kg);
        let r1 = train_multi_worker(&rt, Arc::clone(&kg), &c, &mut s1).unwrap();
        let r2 = train_multi_worker(&rt, Arc::clone(&kg), &c, &mut s2).unwrap();
        assert_eq!(r1.loss_curve, r2.loss_curve);
        assert_eq!(s1.entities.data, s2.entities.data);
        assert!(r1.loss_curve.iter().all(|l| l.is_finite()));
    }

    #[test]
    fn report_attributes_phases_like_the_single_trainer() {
        let rt = MockRuntime::new();
        let kg = kg();
        let mut state = mk_state(&rt, &kg);
        let r = train_multi_worker(&rt, kg, &cfg(2), &mut state).unwrap();
        for bucket in ["sample", "build_dag", "execute", "allreduce", "optimize"] {
            assert!(
                r.phases.iter().any(|(n, _)| n == bucket),
                "missing phase bucket {bucket}: {:?}",
                r.phases
            );
        }
    }

    #[test]
    fn ring_model_monotone() {
        let t1 = 1.0;
        let s2 = modeled_speedup(t1, 1 << 20, 2, 10e9, 5e-6);
        let s4 = modeled_speedup(t1, 1 << 20, 4, 10e9, 5e-6);
        let s8 = modeled_speedup(t1, 1 << 20, 8, 10e9, 5e-6);
        assert!(s2 > 1.5 && s4 > s2 && s8 > s4, "{s2} {s4} {s8}");
        assert_eq!(ring_allreduce_secs(1 << 20, 1, 1e9, 1e-6), 0.0);
    }
}
