//! Single-hop (link-prediction) trainer — the Table 2 runtime comparison.
//!
//! ComplEx over plain triples, epoch-based like Marius/PBG/SMORE measure
//! it: one epoch = one pass over the training edges in shuffled order,
//! batched through the fused `complex_score` artifact (loss + all
//! gradients in one launch), sparse Adam on both tables.
//!
//! There is no QueryDAG here (one fused launch scores a whole triple
//! batch), so of the shared [`super::step`] pipeline this driver uses the
//! reduce + optimize tail — [`Grads`] scatter-adds and [`step::optimize`]
//! — plus the same phase-bucket vocabulary (`sample` / `gather` /
//! `execute` / `reduce` / `optimize`) in [`SingleHopReport::phases`].

use std::sync::Arc;

use anyhow::Result;

use super::step;
use crate::exec::Grads;
use crate::kg::KgStore;
use crate::model::ModelState;
use crate::optim::AdamConfig;
use crate::runtime::{HostTensor, Runtime};
use crate::util::rng::Rng;
use crate::util::timer::PhaseTimer;

/// Result of an epoch-based single-hop run.
#[derive(Debug, Clone, Default)]
pub struct SingleHopReport {
    pub epoch_secs: Vec<f64>,
    pub triples_per_sec: f64,
    pub loss_curve: Vec<f64>,
    /// phase attribution of the run's wall clock
    pub phases: Vec<(String, f64)>,
}

/// Train ComplEx for `epochs` epochs; `batch` is the triple batch size
/// (bucketed to the compiled artifact sizes).
pub fn train_complex(
    rt: &dyn Runtime,
    kg: Arc<KgStore>,
    state: &mut ModelState,
    epochs: usize,
    batch: usize,
    lr: f32,
    seed: u64,
) -> Result<SingleHopReport> {
    let dims = &rt.manifest().dims;
    let n_neg = dims.n_neg;
    let bucket = dims.bucket_for(batch.min(dims.b_max));
    let adam = AdamConfig { lr, ..Default::default() };
    let mut rng = Rng::new(seed);
    let mut report = SingleHopReport::default();
    let mut phases = PhaseTimer::default();
    let mut order: Vec<u32> = (0..kg.train.len() as u32).collect();

    for _epoch in 0..epochs {
        let sw = std::time::Instant::now();
        rng.shuffle(&mut order);
        let mut epoch_loss = 0.0f64;
        let mut seen = 0usize;
        for chunk in order.chunks(bucket) {
            let b = chunk.len();
            // ---- sample: triple ids + fresh uniform negatives ------------
            let (h_ids, r_ids, t_ids, negs) = phases.time("sample", || {
                let mut h_ids = Vec::with_capacity(b);
                let mut r_ids = Vec::with_capacity(b);
                let mut t_ids = Vec::with_capacity(b);
                let mut negs: Vec<Vec<u32>> = Vec::with_capacity(b);
                for &ti in chunk {
                    let t = kg.train[ti as usize];
                    h_ids.push(t.h);
                    r_ids.push(t.r);
                    t_ids.push(t.t);
                    negs.push(
                        (0..n_neg)
                            .map(|_| rng.below(kg.n_entities) as u32)
                            .collect(),
                    );
                }
                (h_ids, r_ids, t_ids, negs)
            });

            // ---- gather: coalesce embedding rows into the bucket ---------
            let inputs = phases.time("gather", || {
                let neg_refs: Vec<&[u32]> = negs.iter().map(Vec::as_slice).collect();
                let mut mask = HostTensor::zeros(vec![bucket]);
                mask.data[..b].fill(1.0);
                vec![
                    state.entities.gather(&h_ids, bucket),
                    state.relations.gather(&r_ids, bucket),
                    state.entities.gather(&t_ids, bucket),
                    state.entities.gather_nested(&neg_refs, bucket, n_neg),
                    mask,
                ]
            });

            // ---- execute: one fused loss+grads launch --------------------
            let name = format!("complex_score_fwd_b{bucket}");
            let out = phases.time("execute", || rt.execute(&name, &inputs))?;
            epoch_loss += out[0].data[0] as f64;
            seen += b;

            // ---- reduce: scatter grads into the shared accumulator -------
            let mut grads = Grads::default();
            phases.time("reduce", || {
                let (g_h, g_r, g_pos, g_neg) = (&out[1], &out[2], &out[3], &out[4]);
                let ed = state.ent_dim;
                for i in 0..b {
                    Grads::add_rows(&mut grads.ent, h_ids[i], g_h.row(i));
                    Grads::add_rows(&mut grads.rel, r_ids[i], g_r.row(i));
                    Grads::add_rows(&mut grads.ent, t_ids[i], g_pos.row(i));
                    for (j, &nid) in negs[i].iter().enumerate() {
                        let base = i * n_neg * ed + j * ed;
                        Grads::add_rows(&mut grads.ent, nid, &g_neg.data[base..base + ed]);
                    }
                }
                grads.n_queries = b;
                grads.normalize();
            });

            // ---- optimize: the shared Adam tail --------------------------
            phases.time("optimize", || step::optimize(state, &grads, &adam));
        }
        report.epoch_secs.push(sw.elapsed().as_secs_f64());
        report.loss_curve.push(epoch_loss / seen.max(1) as f64);
    }
    let total: f64 = report.epoch_secs.iter().sum();
    report.triples_per_sec = (kg.train.len() * epochs) as f64 / total.max(1e-9);
    report.phases = phases.buckets.clone();
    Ok(report)
}
