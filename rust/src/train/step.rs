//! The shared training-step pipeline: **sample → build DAGs → execute →
//! reduce → optimize**, with uniform phase attribution.
//!
//! All three trainers are thin drivers over this module:
//!
//! * [`super::Trainer::train`] — samples (sync rng or async stream), feeds
//!   [`StepPipeline::execute_step`]; under `Pipelining::Async` a
//!   [`DagPrefetcher`] builds step N+1's DAGs while step N's artifacts
//!   execute (double-buffered step pipelining — §4.3's heterogeneous
//!   pipeline one layer up).
//! * [`super::train_multi_worker`] — W workers each drive
//!   [`StepPipeline::run_batch`] over their shard (per-worker
//!   [`EngineSession`]s persist across steps), then gradients fold through
//!   [`crate::exec::Grads::accumulate`] in worker order and one
//!   [`optimize`] applies.
//! * [`super::train_complex`] — no DAGs (fused single-launch scoring), but
//!   the same [`crate::exec::Grads`] reduce + [`optimize`] tail and the
//!   same phase-bucket vocabulary.
//!
//! The pipeline owns an [`EngineSession`], so back-to-back DAGs within and
//! across steps reuse one warm gather worker — zero per-run thread spawns.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{bail, Result};

use crate::config::Batching;
use crate::exec::{EngineSession, Grads, StepStats};
use crate::kg::KgStore;
use crate::model::ModelState;
use crate::optim::AdamConfig;
use crate::query::{Pattern, QueryDag};
use crate::sampler::{ground, negatives, GroundedQuery};
use crate::util::rng::Rng;
use crate::util::timer::PhaseTimer;

/// Synchronous on-the-critical-path sampling (the `Pipelining::Sync`
/// baseline): draw up to `count` grounded queries with negatives attached.
pub fn sample_sync(
    kg: &KgStore,
    rng: &mut Rng,
    patterns: &[Pattern],
    count: usize,
    n_neg: usize,
) -> Vec<GroundedQuery> {
    let mut out = Vec::with_capacity(count);
    let mut guard = 0usize;
    while out.len() < count && guard < count * 30 {
        guard += 1;
        let p = *rng.choice(patterns);
        if let Some(mut q) = ground(kg, rng, p) {
            q.negatives = negatives(kg, rng, q.answer, None, n_neg);
            out.push(q);
        }
    }
    out
}

/// Build the step's DAG(s) per the batching policy: one fused DAG
/// (operator-level), one per structure group (query-level), or one per
/// query (the SQE-like per-query baseline).
pub fn build_dags(
    batching: Batching,
    batch: &[GroundedQuery],
    neg_ok: bool,
) -> Result<Vec<QueryDag>> {
    match batching {
        Batching::OperatorLevel => {
            let mut dag = QueryDag::default();
            for q in batch {
                dag.add_query(&q.tree, q.answer, q.negatives.clone(), q.pattern.name(),
                    neg_ok)?;
            }
            dag.add_gradient_nodes();
            Ok(vec![dag])
        }
        Batching::QueryLevel => {
            // fragment by structure: one fused DAG per pattern group
            let mut groups: std::collections::BTreeMap<&str, Vec<&GroundedQuery>> =
                Default::default();
            for q in batch {
                groups.entry(q.pattern.name()).or_default().push(q);
            }
            groups
                .into_values()
                .map(|qs| {
                    let mut dag = QueryDag::default();
                    for q in qs {
                        dag.add_query(&q.tree, q.answer, q.negatives.clone(),
                            q.pattern.name(), neg_ok)?;
                    }
                    dag.add_gradient_nodes();
                    Ok(dag)
                })
                .collect()
        }
        Batching::PerQuery => batch
            .iter()
            .map(|q| {
                let mut dag = QueryDag::default();
                dag.add_query(&q.tree, q.answer, q.negatives.clone(),
                    q.pattern.name(), neg_ok)?;
                dag.add_gradient_nodes();
                Ok(dag)
            })
            .collect(),
    }
}

/// Apply accumulated (already-normalized) gradients: dense + sparse Adam,
/// bumping the optimizer step — the single optimize stage every trainer
/// routes through.
pub fn optimize(state: &mut ModelState, grads: &Grads, adam: &AdamConfig) {
    state.step += 1;
    let step = state.step;
    // delta-publish bookkeeping: these are exactly the embedding rows
    // `apply_sparse` mutates below, so a COW snapshot publish can copy
    // only their pages. Dense params are not tracked — `apply_dense`
    // touches every element, so publishes always re-copy them wholesale.
    state.dirty.ent.extend(grads.ent.keys().copied());
    state.dirty.rel.extend(grads.rel.keys().copied());
    for (name, g) in &grads.dense {
        if let Some(p) = state.dense.get_mut(name) {
            adam.apply_dense(p, g, step);
        }
    }
    adam.apply_sparse(&mut state.entities, &grads.ent, step);
    adam.apply_sparse(&mut state.relations, &grads.rel, step);
}

/// Execution telemetry of one step (or one worker's shard of it),
/// aggregated over the step's DAGs.
#[derive(Debug, Clone, Default)]
pub struct ExecStats {
    pub queries: usize,
    pub operators: usize,
    /// artifact invocations (= fused kernel launches)
    pub launches: usize,
    pub padded_rows: usize,
    /// total bucket rows (filled + padding) — pad% denominator
    pub bucket_rows: usize,
    pub peak_live_bytes: usize,
    /// wall-clock of DAG construction (`run_batch` only)
    pub build_secs: f64,
    /// wall-clock of the execute stage end to end
    pub execute_wall_secs: f64,
    /// engine sub-attribution (see [`StepStats`])
    pub gather_secs: f64,
    pub execute_secs: f64,
    pub overlap_secs: f64,
    pub worker_idle_secs: f64,
    pub gather_wait_secs: f64,
    /// staging/output buffers recycled by the session's tensor pool
    pub pool_hits: u64,
    /// pool checkouts that had to allocate (cold shapes; zero once warm)
    pub pool_misses: u64,
    /// high-water bytes parked in the session pool
    pub peak_pool_bytes: usize,
    /// per-pattern loss observations (adaptive-sampler feedback)
    pub per_pattern: Vec<(&'static str, f64, usize)>,
}

impl ExecStats {
    /// Fold one DAG run's telemetry in.
    pub fn absorb(&mut self, stats: StepStats) {
        self.queries += stats.n_queries;
        self.operators += stats.operators;
        self.launches += stats.executions;
        self.padded_rows += stats.padded_rows;
        self.bucket_rows += stats.bucket_rows;
        self.peak_live_bytes = self.peak_live_bytes.max(stats.peak_live_bytes);
        self.gather_secs += stats.gather_secs;
        self.execute_secs += stats.execute_secs;
        self.overlap_secs += stats.overlap_secs;
        self.worker_idle_secs += stats.worker_idle_secs;
        self.gather_wait_secs += stats.gather_wait_secs;
        self.pool_hits += stats.pool_hits;
        self.pool_misses += stats.pool_misses;
        self.peak_pool_bytes = self.peak_pool_bytes.max(stats.peak_pool_bytes);
        self.per_pattern.extend(stats.per_pattern_loss);
    }

    /// Attribute the engine's execute sub-buckets into a phase timer,
    /// scaled by `scale` (1.0 for a single trainer; `1/workers` for
    /// summed-across-workers stats so they stay per-worker means). The one
    /// place the `execute/*` bucket vocabulary is defined — the single and
    /// multi-worker trainers both route through it.
    pub fn attribute_execute(&self, phases: &mut PhaseTimer, scale: f64) {
        phases.add("execute/gather", self.gather_secs * scale);
        phases.add("execute/artifacts", self.execute_secs * scale);
        phases.add("execute/overlap", self.overlap_secs * scale);
        phases.add("execute/worker_idle", self.worker_idle_secs * scale);
        phases.add("execute/gather_wait", self.gather_wait_secs * scale);
    }

    /// Fold another worker's shard telemetry in (sums; divide by the
    /// worker count for per-worker means of the wall-clock fields).
    pub fn merge(&mut self, other: ExecStats) {
        self.queries += other.queries;
        self.operators += other.operators;
        self.launches += other.launches;
        self.padded_rows += other.padded_rows;
        self.bucket_rows += other.bucket_rows;
        self.peak_live_bytes = self.peak_live_bytes.max(other.peak_live_bytes);
        self.build_secs += other.build_secs;
        self.execute_wall_secs += other.execute_wall_secs;
        self.gather_secs += other.gather_secs;
        self.execute_secs += other.execute_secs;
        self.overlap_secs += other.overlap_secs;
        self.worker_idle_secs += other.worker_idle_secs;
        self.gather_wait_secs += other.gather_wait_secs;
        self.pool_hits += other.pool_hits;
        self.pool_misses += other.pool_misses;
        self.peak_pool_bytes = self.peak_pool_bytes.max(other.peak_pool_bytes);
        self.per_pattern.extend(other.per_pattern);
    }
}

/// Outcome of one full optimizer step through [`StepPipeline::execute_step`].
#[derive(Debug, Clone, Default)]
pub struct StepOutcome {
    /// mean per-query loss
    pub mean_loss: f64,
    pub exec: ExecStats,
}

/// One trainer's (or one data-parallel worker's) step pipeline: a warm
/// [`EngineSession`], the optimizer config, and the batching policy.
pub struct StepPipeline<'a> {
    pub session: EngineSession<'a>,
    pub adam: AdamConfig,
    pub batching: Batching,
    pub supports_neg: bool,
}

impl<'a> StepPipeline<'a> {
    pub fn new(
        session: EngineSession<'a>,
        adam: AdamConfig,
        batching: Batching,
        supports_neg: bool,
    ) -> StepPipeline<'a> {
        StepPipeline { session, adam, batching, supports_neg }
    }

    /// Build this pipeline's DAG(s) for one batch.
    pub fn build_dags(&self, batch: &[GroundedQuery]) -> Result<Vec<QueryDag>> {
        build_dags(self.batching, batch, self.supports_neg)
    }

    /// Build + execute one batch, accumulating into `grads` — the
    /// data-parallel worker's half-step (reduce and optimize happen on the
    /// driver after the worker-order all-reduce).
    pub fn run_batch(
        &mut self,
        batch: &[GroundedQuery],
        state: &ModelState,
        grads: &mut Grads,
    ) -> Result<ExecStats> {
        let mut exec = ExecStats::default();
        let t0 = Instant::now();
        let dags = self.build_dags(batch)?;
        exec.build_secs = t0.elapsed().as_secs_f64();
        let t1 = Instant::now();
        for dag in &dags {
            exec.absorb(self.session.run(dag, state, grads)?);
        }
        exec.execute_wall_secs = t1.elapsed().as_secs_f64();
        Ok(exec)
    }

    /// Execute pre-built DAGs, reduce, and optimize — one full step with
    /// the uniform phase attribution (`execute` + engine sub-buckets,
    /// `optimize`).
    pub fn execute_step(
        &mut self,
        dags: &[QueryDag],
        state: &mut ModelState,
        phases: &mut PhaseTimer,
    ) -> Result<StepOutcome> {
        let mut grads = Grads::default();
        let mut exec = ExecStats::default();
        let session = &mut self.session;
        phases.time("execute", || -> Result<()> {
            let t1 = Instant::now();
            for dag in dags {
                exec.absorb(session.run(dag, state, &mut grads)?);
            }
            exec.execute_wall_secs = t1.elapsed().as_secs_f64();
            Ok(())
        })?;
        // sub-attribution of the execute phase (pipelined engine): overlap
        // is gather time hidden under artifact execution; worker_idle /
        // gather_wait are the persistent-worker contention counters (worker
        // starved of jobs vs main thread starved of prefetches)
        exec.attribute_execute(phases, 1.0);

        // ---- reduce + optimize
        grads.normalize();
        let mean_loss = grads.loss / grads.n_queries.max(1) as f64;
        phases.time("optimize", || optimize(state, &grads, &self.adam));
        Ok(StepOutcome { mean_loss, exec })
    }
}

/// Double-buffered DAG building: a session-long builder thread turns
/// sampled batches into DAGs off the critical path, so step N+1's DAGs
/// build while step N's artifacts execute. Safe (no raw pointers): batches
/// move in, DAGs move out. Submissions are FIFO; numerics are untouched —
/// the same batches produce the same DAGs, only earlier.
pub struct DagPrefetcher {
    job_tx: Option<Sender<Vec<GroundedQuery>>>,
    out_rx: Receiver<Result<(usize, Vec<QueryDag>)>>,
    handle: Option<JoinHandle<()>>,
}

impl DagPrefetcher {
    pub fn spawn(batching: Batching, supports_neg: bool) -> DagPrefetcher {
        let (job_tx, job_rx) = channel::<Vec<GroundedQuery>>();
        let (out_tx, out_rx) = channel();
        let handle = std::thread::spawn(move || {
            while let Ok(batch) = job_rx.recv() {
                let n = batch.len();
                let built = build_dags(batching, &batch, supports_neg).map(|d| (n, d));
                if out_tx.send(built).is_err() {
                    break;
                }
            }
        });
        DagPrefetcher { job_tx: Some(job_tx), out_rx, handle: Some(handle) }
    }

    /// Queue the next step's batch for building.
    pub fn submit(&self, batch: Vec<GroundedQuery>) {
        if let Some(tx) = &self.job_tx {
            tx.send(batch).expect("DAG builder hung up");
        }
    }

    /// Block until the oldest submitted batch is built; returns its query
    /// count and DAGs.
    pub fn recv(&self) -> Result<(usize, Vec<QueryDag>)> {
        match self.out_rx.recv() {
            Ok(built) => built,
            Err(_) => bail!("DAG builder died"),
        }
    }
}

impl Drop for DagPrefetcher {
    fn drop(&mut self) {
        self.job_tx.take(); // hang up: the builder's recv errors and it exits
        while self.out_rx.try_recv().is_ok() {} // discard unclaimed builds
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::EngineConfig;
    use crate::kg::KgSpec;
    use crate::runtime::{MockRuntime, Runtime};
    use std::sync::Arc;

    fn kg() -> Arc<KgStore> {
        Arc::new(KgSpec::preset("toy", 1.0).unwrap().generate().unwrap())
    }

    fn sample(kg: &KgStore, n: usize) -> Vec<GroundedQuery> {
        let mut rng = Rng::new(11);
        sample_sync(kg, &mut rng, &[Pattern::P1, Pattern::I2], n, 2)
    }

    #[test]
    fn build_dags_respects_the_batching_policy() {
        let kg = kg();
        let batch = sample(&kg, 12);
        assert!(!batch.is_empty());
        let op = build_dags(Batching::OperatorLevel, &batch, true).unwrap();
        assert_eq!(op.len(), 1);
        let pq = build_dags(Batching::PerQuery, &batch, true).unwrap();
        assert_eq!(pq.len(), batch.len());
        let ql = build_dags(Batching::QueryLevel, &batch, true).unwrap();
        assert!(ql.len() <= 2, "at most one group per pattern");
    }

    #[test]
    fn prefetcher_builds_identically_to_inline_building() {
        let kg = kg();
        let b1 = sample(&kg, 8);
        let b2 = sample(&kg, 8);
        let p = DagPrefetcher::spawn(Batching::OperatorLevel, true);
        p.submit(b1.clone());
        p.submit(b2.clone());
        for b in [b1, b2] {
            let (n, dags) = p.recv().unwrap();
            assert_eq!(n, b.len());
            let inline = build_dags(Batching::OperatorLevel, &b, true).unwrap();
            assert_eq!(dags.len(), inline.len());
            assert_eq!(dags[0].len(), inline[0].len());
            assert_eq!(dags[0].queries.len(), inline[0].queries.len());
        }
    }

    #[test]
    fn pipeline_step_trains_and_attributes_phases() {
        let rt = MockRuntime::new();
        let kg = kg();
        let mut state = ModelState::init(
            rt.manifest(), "mock", kg.n_entities, kg.n_relations, None, 5,
        )
        .unwrap();
        let before = state.entities.data.clone();
        let mut pipeline = StepPipeline::new(
            EngineSession::new(&rt, EngineConfig::default()),
            AdamConfig::default(),
            Batching::OperatorLevel,
            true,
        );
        let batch = sample(&kg, 16);
        let dags = pipeline.build_dags(&batch).unwrap();
        let mut phases = PhaseTimer::default();
        let outcome = pipeline.execute_step(&dags, &mut state, &mut phases).unwrap();
        assert!(outcome.mean_loss.is_finite());
        assert_eq!(outcome.exec.queries, batch.len());
        assert_ne!(state.entities.data, before, "optimize must move embeddings");
        assert_eq!(state.step, 1);
        for bucket in ["execute", "execute/gather", "execute/artifacts", "optimize"] {
            assert!(
                phases.buckets.iter().any(|(n, _)| n == bucket),
                "missing phase bucket {bucket}"
            );
        }
    }

    #[test]
    fn run_batch_accumulates_without_optimizing() {
        let rt = MockRuntime::new();
        let kg = kg();
        let state = ModelState::init(
            rt.manifest(), "mock", kg.n_entities, kg.n_relations, None, 5,
        )
        .unwrap();
        let mut pipeline = StepPipeline::new(
            EngineSession::new(&rt, EngineConfig::default()),
            AdamConfig::default(),
            Batching::OperatorLevel,
            true,
        );
        let batch = sample(&kg, 8);
        let mut grads = Grads::default();
        let exec = pipeline.run_batch(&batch, &state, &mut grads).unwrap();
        assert_eq!(exec.queries, batch.len());
        assert_eq!(grads.n_queries, batch.len());
        assert!(exec.launches > 0);
        assert!(
            exec.pool_hits + exec.pool_misses > 0,
            "pool telemetry must flow through ExecStats"
        );
        assert!(!grads.ent.is_empty());
        assert_eq!(state.step, 0, "run_batch must not touch the optimizer");
    }
}
