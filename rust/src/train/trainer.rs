//! Training loops: NGDB-Zoo's operator-level trainer and the two baselines
//! the paper measures against, unified behind one loop with two knobs
//! (Fig. 2 / Fig. 3):
//!
//! * `Batching::OperatorLevel` — one fused DAG per step, cross-query
//!   operator pools, Max-Fillness scheduling (ours);
//! * `Batching::QueryLevel` — queries grouped by identical structure, one
//!   fused DAG *per structure group* (KGReasoning-style fragmentation);
//! * `Batching::PerQuery` — one DAG per query with singleton batches
//!   (SQE-proxy, Fig. 2a's kernel stream).
//!
//! `Pipelining::Sync` generates queries on the critical path;
//! `Pipelining::Async` consumes the producer-thread stream (§4.3).

use std::sync::Arc;

use anyhow::{bail, Result};

use crate::config::{Batching, ExperimentConfig, Pipelining};
use crate::exec::{Engine, EngineConfig, Grads};
use crate::kg::KgStore;
use crate::metrics::{MemoryEstimate, ThroughputMeter, TsvLogger};
use crate::model::ModelState;
use crate::optim::AdamConfig;
use crate::query::{Pattern, QueryDag};
use crate::runtime::Runtime;
use crate::sampler::{ground, GroundedQuery, SamplerStream};
use crate::semantic::SemanticSource;
use crate::util::rng::Rng;
use crate::util::timer::{PhaseTimer, Stopwatch};

/// Outcome of a training run.
#[derive(Debug, Clone, Default)]
pub struct TrainReport {
    /// mean loss per step
    pub loss_curve: Vec<f64>,
    pub qps: f64,
    pub steps: usize,
    pub queries: u64,
    pub mem: MemoryEstimate,
    pub ops_per_launch: f64,
    pub padded_frac: f64,
    /// phase attribution of the run's wall clock
    pub phases: Vec<(String, f64)>,
}

/// Drives one model over one graph per the experiment config.
pub struct Trainer<'a> {
    pub rt: &'a dyn Runtime,
    pub kg: Arc<KgStore>,
    pub cfg: ExperimentConfig,
    pub adam: AdamConfig,
    pub semantic: Option<&'a dyn SemanticSource>,
}

impl<'a> Trainer<'a> {
    pub fn new(rt: &'a dyn Runtime, kg: Arc<KgStore>, cfg: ExperimentConfig) -> Trainer<'a> {
        let adam = AdamConfig { lr: cfg.lr as f32, ..Default::default() };
        Trainer { rt, kg, cfg, adam, semantic: None }
    }

    pub fn with_semantic(mut self, source: &'a dyn SemanticSource) -> Trainer<'a> {
        self.semantic = Some(source);
        self
    }

    fn engine(&self) -> Engine<'a> {
        let ecfg = EngineConfig {
            force_singleton: self.cfg.batching == Batching::PerQuery,
            ..Default::default()
        };
        match self.semantic {
            Some(s) => Engine::with_semantic(self.rt, ecfg, s),
            None => Engine::new(self.rt, ecfg),
        }
    }

    /// Run `cfg.steps` optimizer steps, mutating `state`.
    pub fn train(&self, state: &mut ModelState) -> Result<TrainReport> {
        let supports_neg = crate::config::model_supports_negation(&state.model);
        if self.cfg.patterns.iter().any(|p| p.has_negation()) && !supports_neg {
            bail!("model {} cannot train negation patterns", state.model);
        }
        let n_neg = self.rt.manifest().dims.n_neg;
        let engine = self.engine();
        let mut meter = ThroughputMeter::new();
        let mut phases = PhaseTimer::default();
        let mut logger = TsvLogger::open(
            self.cfg.log_path.as_deref(),
            "step\tloss\tqps\tops_per_launch\tpeak_live_bytes",
        )?;
        let mut report = TrainReport::default();

        // async pipeline (producers) or a local synchronous sampler
        let stream = match self.cfg.pipelining {
            Pipelining::Async => Some(SamplerStream::spawn(
                Arc::clone(&self.kg),
                self.cfg.sampler(n_neg),
            )),
            Pipelining::Sync => None,
        };
        let mut sync_rng = Rng::new(self.cfg.seed ^ 0x5A);

        let mut peak_live = 0usize;
        for step in 0..self.cfg.steps {
            let sw = Stopwatch::new();
            // ---- sample -----------------------------------------------------
            let batch: Vec<GroundedQuery> = phases.time("sample", || match &stream {
                Some(s) => s.recv_batch(self.cfg.batch_queries),
                None => self.sample_sync(&mut sync_rng, n_neg),
            });
            if batch.is_empty() {
                bail!("sampler produced no queries");
            }

            // ---- build DAG(s) per batching policy ---------------------------
            let dags: Vec<QueryDag> = phases.time("build_dag", || {
                self.build_dags(&batch, supports_neg)
            })?;

            // ---- execute -----------------------------------------------------
            let mut grads = Grads::default();
            let mut step_ops = 0usize;
            let mut step_launch = 0usize;
            let mut step_pad = 0usize;
            let (mut step_gather, mut step_exec, mut step_overlap) = (0.0f64, 0.0f64, 0.0f64);
            let (mut step_idle, mut step_wait) = (0.0f64, 0.0f64);
            let mut per_pattern: Vec<(&'static str, f64, usize)> = Vec::new();
            phases.time("execute", || -> Result<()> {
                for dag in &dags {
                    let stats = engine.run(dag, state, &mut grads)?;
                    step_ops += stats.operators;
                    step_launch += stats.executions;
                    step_pad += stats.padded_rows;
                    step_gather += stats.gather_secs;
                    step_exec += stats.execute_secs;
                    step_overlap += stats.overlap_secs;
                    step_idle += stats.worker_idle_secs;
                    step_wait += stats.gather_wait_secs;
                    peak_live = peak_live.max(stats.peak_live_bytes);
                    per_pattern.extend(stats.per_pattern_loss);
                }
                Ok(())
            })?;
            // sub-attribution of the execute phase (pipelined engine):
            // overlap is gather time hidden under artifact execution;
            // worker_idle / gather_wait are the persistent-worker contention
            // counters (worker starved of jobs vs main thread starved of
            // prefetches)
            phases.add("execute/gather", step_gather);
            phases.add("execute/artifacts", step_exec);
            phases.add("execute/overlap", step_overlap);
            phases.add("execute/worker_idle", step_idle);
            phases.add("execute/gather_wait", step_wait);

            // ---- optimize ----------------------------------------------------
            grads.normalize();
            let mean_loss = grads.loss / grads.n_queries.max(1) as f64;
            phases.time("optimize", || self.apply(state, &grads));

            // ---- feedback + metrics ------------------------------------------
            if let Some(s) = &stream {
                for (pat, loss, count) in per_pattern {
                    if count > 0 {
                        if let Ok(p) = Pattern::from_name(pat) {
                            s.feedback(p, loss / count as f64);
                        }
                    }
                }
            }
            meter.tick(batch.len(), step_ops, step_launch, step_pad, sw.elapsed_secs());
            report.loss_curve.push(mean_loss);
            logger.row(&[
                step.to_string(),
                format!("{mean_loss:.6}"),
                format!("{:.1}", meter.qps()),
                format!("{:.2}", meter.ops_per_launch()),
                peak_live.to_string(),
            ]);
        }

        if let Some(s) = stream {
            s.shutdown();
        }
        report.steps = self.cfg.steps;
        report.queries = meter.queries;
        report.qps = meter.qps();
        report.ops_per_launch = meter.ops_per_launch();
        report.padded_frac = meter.padded_rows as f64
            / (meter.operators + meter.padded_rows).max(1) as f64;
        report.mem = MemoryEstimate {
            state_bytes: state.bytes(),
            peak_live_bytes: peak_live,
            resident_bytes: self.semantic.map_or(0, |s| s.resident_bytes()),
            encoder_bytes: 0,
        };
        report.phases = phases.buckets.clone();
        Ok(report)
    }

    fn sample_sync(&self, rng: &mut Rng, n_neg: usize) -> Vec<GroundedQuery> {
        let mut out = Vec::with_capacity(self.cfg.batch_queries);
        let mut guard = 0usize;
        while out.len() < self.cfg.batch_queries && guard < self.cfg.batch_queries * 20 {
            guard += 1;
            let p = *rng.choice(&self.cfg.patterns);
            if let Some(mut q) = ground(&self.kg, rng, p) {
                q.negatives =
                    crate::sampler::negatives(&self.kg, rng, q.answer, None, n_neg);
                out.push(q);
            }
        }
        out
    }

    fn build_dags(&self, batch: &[GroundedQuery], neg_ok: bool) -> Result<Vec<QueryDag>> {
        match self.cfg.batching {
            Batching::OperatorLevel => {
                let mut dag = QueryDag::default();
                for q in batch {
                    dag.add_query(&q.tree, q.answer, q.negatives.clone(),
                        q.pattern.name(), neg_ok)?;
                }
                dag.add_gradient_nodes();
                Ok(vec![dag])
            }
            Batching::QueryLevel => {
                // fragment by structure: one fused DAG per pattern group
                let mut groups: std::collections::BTreeMap<&str, Vec<&GroundedQuery>> =
                    Default::default();
                for q in batch {
                    groups.entry(q.pattern.name()).or_default().push(q);
                }
                groups
                    .into_values()
                    .map(|qs| {
                        let mut dag = QueryDag::default();
                        for q in qs {
                            dag.add_query(&q.tree, q.answer, q.negatives.clone(),
                                q.pattern.name(), neg_ok)?;
                        }
                        dag.add_gradient_nodes();
                        Ok(dag)
                    })
                    .collect()
            }
            Batching::PerQuery => batch
                .iter()
                .map(|q| {
                    let mut dag = QueryDag::default();
                    dag.add_query(&q.tree, q.answer, q.negatives.clone(),
                        q.pattern.name(), neg_ok)?;
                    dag.add_gradient_nodes();
                    Ok(dag)
                })
                .collect(),
        }
    }

    /// Apply accumulated gradients (dense + sparse Adam).
    pub fn apply(&self, state: &mut ModelState, grads: &Grads) {
        state.step += 1;
        let step = state.step;
        for (name, g) in &grads.dense {
            if let Some(p) = state.dense.get_mut(name) {
                self.adam.apply_dense(p, g, step);
            }
        }
        self.adam.apply_sparse(&mut state.entities, &grads.ent, step);
        self.adam.apply_sparse(&mut state.relations, &grads.rel, step);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kg::KgSpec;
    use crate::runtime::MockRuntime;

    fn setup(batching: Batching, pipelining: Pipelining) -> (MockRuntime, Arc<KgStore>, ExperimentConfig) {
        let rt = MockRuntime::new();
        let kg = Arc::new(KgSpec::preset("toy", 1.0).unwrap().generate().unwrap());
        let cfg = ExperimentConfig {
            model: "mock".into(),
            steps: 3,
            batch_queries: 16,
            batching,
            pipelining,
            patterns: vec![Pattern::P1, Pattern::P2, Pattern::I2],
            ..Default::default()
        };
        (rt, kg, cfg)
    }

    fn mock_state(rt: &MockRuntime, kg: &KgStore) -> ModelState {
        ModelState::init(
            crate::runtime::Runtime::manifest(rt),
            "mock",
            kg.n_entities,
            kg.n_relations,
            None,
            5,
        )
        .unwrap()
    }

    #[test]
    fn operator_level_trains_and_changes_state() {
        let (rt, kg, cfg) = setup(Batching::OperatorLevel, Pipelining::Async);
        let mut state = mock_state(&rt, &kg);
        let before = state.entities.data.clone();
        let report = Trainer::new(&rt, kg, cfg).train(&mut state).unwrap();
        assert_eq!(report.steps, 3);
        assert_eq!(report.loss_curve.len(), 3);
        assert_ne!(state.entities.data, before, "optimizer must move embeddings");
        assert!(report.qps > 0.0);
    }

    #[test]
    fn all_batching_modes_run_sync_and_async() {
        for b in [Batching::OperatorLevel, Batching::QueryLevel, Batching::PerQuery] {
            for p in [Pipelining::Sync, Pipelining::Async] {
                let (rt, kg, cfg) = setup(b, p);
                let mut state = mock_state(&rt, &kg);
                let r = Trainer::new(&rt, kg, cfg).train(&mut state).unwrap();
                assert!(r.loss_curve.iter().all(|l| l.is_finite()), "{b:?}/{p:?}");
            }
        }
    }

    #[test]
    fn operator_level_fuses_more_than_query_level() {
        let (rt, kg, mut cfg) = setup(Batching::OperatorLevel, Pipelining::Sync);
        cfg.batch_queries = 32;
        let mut state = mock_state(&rt, &kg);
        let r_op = Trainer::new(&rt, Arc::clone(&kg), cfg.clone())
            .train(&mut state)
            .unwrap();
        let (rt2, kg2, mut cfg2) = setup(Batching::PerQuery, Pipelining::Sync);
        cfg2.batch_queries = 32;
        let mut state2 = mock_state(&rt2, &kg2);
        let r_pq = Trainer::new(&rt2, kg2, cfg2).train(&mut state2).unwrap();
        assert!(
            r_op.ops_per_launch > r_pq.ops_per_launch * 1.5,
            "operator-level {} vs per-query {}",
            r_op.ops_per_launch,
            r_pq.ops_per_launch
        );
    }

    #[test]
    fn negation_patterns_rejected_for_unsupported_model() {
        // the config layer filters; the trainer double-checks
        let (rt, kg, mut cfg) = setup(Batching::OperatorLevel, Pipelining::Sync);
        cfg.patterns = vec![Pattern::In2];
        let mut state = mock_state(&rt, &kg);
        state.model = "gqe".into();
        assert!(Trainer::new(&rt, kg, cfg).train(&mut state).is_err());
    }
}
