//! Training loops: NGDB-Zoo's operator-level trainer and the two baselines
//! the paper measures against, unified behind one loop with two knobs
//! (Fig. 2 / Fig. 3):
//!
//! * `Batching::OperatorLevel` — one fused DAG per step, cross-query
//!   operator pools, Max-Fillness scheduling (ours);
//! * `Batching::QueryLevel` — queries grouped by identical structure, one
//!   fused DAG *per structure group* (KGReasoning-style fragmentation);
//! * `Batching::PerQuery` — one DAG per query with singleton batches
//!   (SQE-proxy, Fig. 2a's kernel stream).
//!
//! The trainer is a thin driver over the shared [`step`] pipeline: it
//! samples, then hands DAGs to [`step::StepPipeline::execute_step`], whose
//! warm [`crate::exec::EngineSession`] persists across all steps (and all
//! DAGs of a step — the per-query baseline no longer spawns a gather
//! worker per query).
//!
//! `Pipelining::Sync` samples and builds DAGs on the critical path;
//! `Pipelining::Async` consumes the producer-thread stream (§4.3) with
//! exact-size receives *and* double-buffers DAG construction through a
//! [`step::DagPrefetcher`] — step N+1's DAGs build while step N's
//! artifacts execute. Both paths replay deterministically per seed (Async
//! needs a single producer thread: exact receives then make the query
//! sequence a pure function of the seed). Adaptive feedback under Async
//! reaches the producers one step later than Sync would apply it — the
//! price of sampling ahead; with `adaptive_lambda = 0` the sequences are
//! identical.

use std::sync::{Arc, Mutex};

use anyhow::{bail, Result};

use super::checkpoint::AutoCheckpointer;
use super::step::{self, DagPrefetcher, StepPipeline};
use crate::config::{Batching, ExperimentConfig, Pipelining};
use crate::exec::{EngineConfig, EngineSession, Grads};
use crate::kg::KgStore;
use crate::metrics::{MemoryEstimate, ThroughputMeter, TsvLogger};
use crate::model::{ModelState, SnapshotCell};
use crate::optim::AdamConfig;
use crate::query::Pattern;
use crate::runtime::Runtime;
use crate::sampler::SamplerStream;
use crate::semantic::SemanticSource;
use crate::util::rng::Rng;
use crate::util::timer::{PhaseTimer, Stopwatch};

/// Outcome of a training run.
#[derive(Debug, Clone, Default)]
pub struct TrainReport {
    /// mean loss per step
    pub loss_curve: Vec<f64>,
    pub qps: f64,
    pub steps: usize,
    pub queries: u64,
    pub mem: MemoryEstimate,
    pub ops_per_launch: f64,
    pub padded_frac: f64,
    /// phase attribution of the run's wall clock
    pub phases: Vec<(String, f64)>,
}

/// Drives one model over one graph per the experiment config.
pub struct Trainer<'a> {
    pub rt: &'a dyn Runtime,
    pub kg: Arc<KgStore>,
    pub cfg: ExperimentConfig,
    pub adam: AdamConfig,
    pub semantic: Option<&'a dyn SemanticSource>,
    /// when set, every optimizer step publishes a moment-free
    /// [`crate::model::ModelSnapshot`] here — the train→serve handoff
    /// (see [`crate::serve::QueryService`])
    pub snapshots: Option<Arc<SnapshotCell>>,
    /// when set, periodic crash-safe checkpointing runs after the
    /// optimize stage (Mutex because [`Trainer::train`] takes `&self`;
    /// uncontended — only the trainer thread locks it)
    pub checkpoints: Option<Mutex<AutoCheckpointer>>,
}

impl<'a> Trainer<'a> {
    pub fn new(rt: &'a dyn Runtime, kg: Arc<KgStore>, cfg: ExperimentConfig) -> Trainer<'a> {
        let adam = AdamConfig { lr: cfg.lr as f32, ..Default::default() };
        Trainer { rt, kg, cfg, adam, semantic: None, snapshots: None, checkpoints: None }
    }

    pub fn with_semantic(mut self, source: &'a dyn SemanticSource) -> Trainer<'a> {
        self.semantic = Some(source);
        self
    }

    /// Publish the trained weights into `cell` after every `optimize` —
    /// concurrent [`crate::serve::QueryService`] workers then always read
    /// a fully published snapshot, never a half-updated state.
    pub fn with_snapshots(mut self, cell: Arc<SnapshotCell>) -> Trainer<'a> {
        self.snapshots = Some(cell);
        self
    }

    /// Checkpoint on the auto-checkpointer's cadence after each optimize.
    /// A save that fails permanently logs + counts via
    /// [`super::checkpoint::CheckpointMetrics`] and never fails the step
    /// — serving keeps answering from the last published snapshot either
    /// way.
    pub fn with_checkpoints(mut self, ckpt: AutoCheckpointer) -> Trainer<'a> {
        self.checkpoints = Some(Mutex::new(ckpt));
        self
    }

    /// The checkpoint hook: absorbs this step's dirty rows and saves on
    /// cadence (a no-op without an auto-checkpointer). Must run *before*
    /// [`Trainer::publish_snapshot`], which resets the state's dirty sets.
    pub fn checkpoint_after_step(&self, state: &ModelState) {
        if let Some(ckpt) = &self.checkpoints {
            ckpt.lock().unwrap_or_else(|e| e.into_inner()).after_step(state);
        }
    }

    /// The publish hook: COW delta capture + swap (a no-op without a
    /// cell). When the optimizer's dirty-row tracking lines up with the
    /// previous publish, only the touched shard pages are copied
    /// ([`SnapshotCell::publish_from`]); otherwise a full capture runs —
    /// either way the published snapshot is bitwise identical to
    /// [`crate::model::ModelSnapshot::capture`] of the same state. The copy happens here
    /// on the trainer thread; the serve-side swap is one `Arc` store.
    /// Public so manual steppers ([`Trainer::apply`] users like fig9) can
    /// publish on their own cadence. Fusion provenance is stamped from the
    /// trainer's semantic source, so the serve tier can refuse mismatched
    /// snapshot/source pairs.
    pub fn publish_snapshot(&self, state: &mut ModelState) {
        if let Some(cell) = &self.snapshots {
            cell.publish_from(state, self.semantic.map(|s| s.encoder()));
        }
    }

    /// Stand up this run's step pipeline: one engine session (one warm
    /// gather worker) for the entire training run.
    fn pipeline(&self, supports_neg: bool) -> StepPipeline<'a> {
        let ecfg = EngineConfig {
            force_singleton: self.cfg.batching == Batching::PerQuery,
            ..Default::default()
        };
        let session = match self.semantic {
            Some(s) => EngineSession::with_semantic(self.rt, ecfg, s),
            None => EngineSession::new(self.rt, ecfg),
        };
        StepPipeline::new(session, self.adam, self.cfg.batching, supports_neg)
    }

    /// Run `cfg.steps` optimizer steps, mutating `state`.
    pub fn train(&self, state: &mut ModelState) -> Result<TrainReport> {
        let supports_neg = crate::config::model_supports_negation(&state.model);
        if self.cfg.patterns.iter().any(|p| p.has_negation()) && !supports_neg {
            bail!("model {} cannot train negation patterns", state.model);
        }
        let n_neg = self.rt.manifest().dims.n_neg;
        let mut pipeline = self.pipeline(supports_neg);
        let mut meter = ThroughputMeter::new();
        let mut phases = PhaseTimer::default();
        let mut logger = TsvLogger::open(
            self.cfg.log_path.as_deref(),
            "step\tloss\tqps\tops_per_launch\tpeak_live_bytes",
        )?;
        let mut report = TrainReport::default();
        let mut peak_live = 0usize;

        // Async: producer stream + double-buffered DAG building (prime the
        // builder with step 0's batch). Sync: a local sampler on the
        // critical path.
        let stream = match self.cfg.pipelining {
            Pipelining::Async => Some(SamplerStream::spawn(
                Arc::clone(&self.kg),
                self.cfg.sampler(n_neg),
            )),
            Pipelining::Sync => None,
        };
        let prefetch = stream.as_ref().map(|s| {
            let p = DagPrefetcher::spawn(self.cfg.batching, supports_neg);
            p.submit(phases.time("sample", || s.recv_exact(self.cfg.batch_queries)));
            p
        });
        let mut sync_rng = Rng::new(self.cfg.seed ^ 0x5A);

        for step in 0..self.cfg.steps {
            let sw = Stopwatch::new();
            // ---- sample + build DAG(s); both prefetched under Async ------
            let (n_q, dags) = match (&stream, &prefetch) {
                (Some(s), Some(p)) => {
                    // `build_dag` here is only the *wait* for the builder —
                    // construction itself overlapped step N-1's execution
                    let built = phases.time("build_dag", || p.recv())?;
                    if step + 1 < self.cfg.steps {
                        let next =
                            phases.time("sample", || s.recv_exact(self.cfg.batch_queries));
                        p.submit(next);
                    }
                    built
                }
                _ => {
                    let batch = phases.time("sample", || {
                        step::sample_sync(
                            &self.kg,
                            &mut sync_rng,
                            &self.cfg.patterns,
                            self.cfg.batch_queries,
                            n_neg,
                        )
                    });
                    let dags = phases.time("build_dag", || pipeline.build_dags(&batch))?;
                    (batch.len(), dags)
                }
            };
            if n_q == 0 {
                bail!("sampler produced no queries");
            }

            // ---- execute + reduce + optimize (shared step pipeline) ------
            let outcome = pipeline.execute_step(&dags, state, &mut phases)?;
            peak_live = peak_live.max(outcome.exec.peak_live_bytes);
            // durability first (reads the dirty sets), then the serve
            // handoff (which resets them)
            phases.time("checkpoint", || self.checkpoint_after_step(state));
            self.publish_snapshot(state);

            // ---- feedback + metrics --------------------------------------
            if let Some(s) = &stream {
                for (pat, loss, count) in &outcome.exec.per_pattern {
                    if *count > 0 {
                        if let Ok(p) = Pattern::from_name(pat) {
                            s.feedback(p, *loss / *count as f64);
                        }
                    }
                }
            }
            meter.tick(
                n_q,
                outcome.exec.operators,
                outcome.exec.launches,
                outcome.exec.bucket_rows,
                outcome.exec.padded_rows,
                sw.elapsed_secs(),
            );
            report.loss_curve.push(outcome.mean_loss);
            logger.row(&[
                step.to_string(),
                format!("{:.6}", outcome.mean_loss),
                format!("{:.1}", meter.qps()),
                format!("{:.2}", meter.ops_per_launch()),
                peak_live.to_string(),
            ]);
        }

        drop(prefetch);
        if let Some(s) = stream {
            s.shutdown();
        }
        report.steps = self.cfg.steps;
        report.queries = meter.queries;
        report.qps = meter.qps();
        report.ops_per_launch = meter.ops_per_launch();
        report.padded_frac = meter.padded_frac();
        if logger.flush().is_err() || logger.write_errors() > 0 {
            // the run itself is fine; only the experiment curve is short
            eprintln!(
                "trainer: {} log write(s) failed — the TSV curve is incomplete",
                logger.write_errors()
            );
        }
        report.mem = MemoryEstimate {
            state_bytes: state.bytes(),
            peak_live_bytes: peak_live,
            resident_bytes: self.semantic.map_or(0, |s| s.resident_bytes()),
            encoder_bytes: 0,
        };
        report.phases = phases.buckets.clone();
        Ok(report)
    }

    /// Apply accumulated gradients (dense + sparse Adam) — the shared
    /// pipeline's optimize stage, exposed for manual stepping (fig9).
    pub fn apply(&self, state: &mut ModelState, grads: &Grads) {
        step::optimize(state, grads, &self.adam);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kg::KgSpec;
    use crate::runtime::MockRuntime;

    fn setup(
        batching: Batching,
        pipelining: Pipelining,
    ) -> (MockRuntime, Arc<KgStore>, ExperimentConfig) {
        let rt = MockRuntime::new();
        let kg = Arc::new(KgSpec::preset("toy", 1.0).unwrap().generate().unwrap());
        let cfg = ExperimentConfig {
            model: "mock".into(),
            steps: 3,
            batch_queries: 16,
            batching,
            pipelining,
            patterns: vec![Pattern::P1, Pattern::P2, Pattern::I2],
            ..Default::default()
        };
        (rt, kg, cfg)
    }

    fn mock_state(rt: &MockRuntime, kg: &KgStore) -> ModelState {
        ModelState::init(
            crate::runtime::Runtime::manifest(rt),
            "mock",
            kg.n_entities,
            kg.n_relations,
            None,
            5,
        )
        .unwrap()
    }

    #[test]
    fn operator_level_trains_and_changes_state() {
        let (rt, kg, cfg) = setup(Batching::OperatorLevel, Pipelining::Async);
        let mut state = mock_state(&rt, &kg);
        let before = state.entities.data.clone();
        let report = Trainer::new(&rt, kg, cfg).train(&mut state).unwrap();
        assert_eq!(report.steps, 3);
        assert_eq!(report.loss_curve.len(), 3);
        assert_ne!(state.entities.data, before, "optimizer must move embeddings");
        assert!(report.qps > 0.0);
    }

    #[test]
    fn all_batching_modes_run_sync_and_async() {
        for b in [Batching::OperatorLevel, Batching::QueryLevel, Batching::PerQuery] {
            for p in [Pipelining::Sync, Pipelining::Async] {
                let (rt, kg, cfg) = setup(b, p);
                let mut state = mock_state(&rt, &kg);
                let r = Trainer::new(&rt, kg, cfg).train(&mut state).unwrap();
                assert!(r.loss_curve.iter().all(|l| l.is_finite()), "{b:?}/{p:?}");
            }
        }
    }

    #[test]
    fn operator_level_fuses_more_than_query_level() {
        let (rt, kg, mut cfg) = setup(Batching::OperatorLevel, Pipelining::Sync);
        cfg.batch_queries = 32;
        let mut state = mock_state(&rt, &kg);
        let r_op = Trainer::new(&rt, Arc::clone(&kg), cfg.clone())
            .train(&mut state)
            .unwrap();
        let (rt2, kg2, mut cfg2) = setup(Batching::PerQuery, Pipelining::Sync);
        cfg2.batch_queries = 32;
        let mut state2 = mock_state(&rt2, &kg2);
        let r_pq = Trainer::new(&rt2, kg2, cfg2).train(&mut state2).unwrap();
        assert!(
            r_op.ops_per_launch > r_pq.ops_per_launch * 1.5,
            "operator-level {} vs per-query {}",
            r_op.ops_per_launch,
            r_pq.ops_per_launch
        );
    }

    #[test]
    fn negation_patterns_rejected_for_unsupported_model() {
        // the config layer filters; the trainer double-checks
        let (rt, kg, mut cfg) = setup(Batching::OperatorLevel, Pipelining::Sync);
        cfg.patterns = vec![Pattern::In2];
        let mut state = mock_state(&rt, &kg);
        state.model = "gqe".into();
        assert!(Trainer::new(&rt, kg, cfg).train(&mut state).is_err());
    }

    #[test]
    fn sync_training_replays_deterministically_per_seed() {
        let (rt, kg, cfg) = setup(Batching::OperatorLevel, Pipelining::Sync);
        let run = || {
            let mut state = mock_state(&rt, &kg);
            let r = Trainer::new(&rt, Arc::clone(&kg), cfg.clone())
                .train(&mut state)
                .unwrap();
            (r.loss_curve, state.entities.data)
        };
        let (c1, e1) = run();
        let (c2, e2) = run();
        assert_eq!(c1, c2, "same seed must give the same loss curve");
        assert_eq!(e1, e2, "same seed must give the same final state");
    }

    #[test]
    fn async_single_producer_training_replays_deterministically_per_seed() {
        // With one producer thread, exact-size receives make the query
        // sequence a pure function of the seed — so the double-buffered
        // Async path must replay bit-identically too.
        let (rt, kg, cfg) = setup(Batching::OperatorLevel, Pipelining::Async);
        assert_eq!(cfg.sampler_threads, 1);
        assert_eq!(cfg.adaptive_lambda, 0.0);
        let run = || {
            let mut state = mock_state(&rt, &kg);
            let r = Trainer::new(&rt, Arc::clone(&kg), cfg.clone())
                .train(&mut state)
                .unwrap();
            (r.loss_curve, state.entities.data)
        };
        let (c1, e1) = run();
        let (c2, e2) = run();
        assert_eq!(c1, c2, "same seed must give the same loss curve");
        assert_eq!(e1, e2, "same seed must give the same final state");
    }

    #[test]
    fn training_publishes_a_snapshot_per_step() {
        let (rt, kg, cfg) = setup(Batching::OperatorLevel, Pipelining::Sync);
        let mut state = mock_state(&rt, &kg);
        let cell = Arc::new(crate::model::SnapshotCell::new(
            crate::model::ModelSnapshot::capture(&state),
        ));
        let steps = cfg.steps;
        Trainer::new(&rt, kg, cfg)
            .with_snapshots(Arc::clone(&cell))
            .train(&mut state)
            .unwrap();
        assert_eq!(cell.published(), 1 + steps as u64, "one publish per step");
        let snap = cell.load();
        assert_eq!(snap.step(), steps as u64, "served snapshot is post-optimize");
        assert_eq!(
            snap.entities().to_flat(),
            state.entities.data,
            "published weights match the final trained state bitwise"
        );
        // fresh-state publish #1 must full-capture (no baseline); every
        // later step lines up with the previous publish and deltas
        let totals = cell.publish_totals();
        assert_eq!(totals.full_publishes, 1, "only the first publish is full");
        assert_eq!(totals.delta_publishes, steps as u64 - 1);
    }

    #[test]
    fn training_checkpoints_on_cadence_and_recovers_bitwise() {
        use crate::train::checkpoint::{
            CheckpointPolicy, CheckpointStore, SaveKind,
        };
        let (rt, kg, cfg) = setup(Batching::OperatorLevel, Pipelining::Sync);
        let dir = std::env::temp_dir()
            .join(format!("ngdb_trainer_ckpt_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let mut state = mock_state(&rt, &kg);
        let steps = cfg.steps;
        let ckpt = AutoCheckpointer::new(
            CheckpointStore::open(&dir),
            CheckpointPolicy { every_steps: 1, ..Default::default() },
        );
        let metrics = ckpt.metrics();
        Trainer::new(&rt, kg, cfg)
            .with_checkpoints(ckpt)
            .train(&mut state)
            .unwrap();
        assert_eq!(
            metrics.saves_full.get() + metrics.saves_delta.get(),
            steps as u64,
            "one committed generation per step"
        );
        assert_eq!(metrics.saves_full.get(), 1, "only the base save is full");
        assert_eq!(metrics.failures_full.get() + metrics.failures_delta.get(), 0);
        // a cold process (fresh store, no anchor) recovers the final
        // trained state bitwise from base + deltas
        let mut restored = ModelState::init(
            crate::runtime::Runtime::manifest(&rt),
            "mock",
            state.entities.rows,
            state.relations.rows,
            None,
            5,
        )
        .unwrap();
        let store = CheckpointStore::open(&dir);
        store.load_latest(&mut restored).unwrap();
        assert_eq!(restored.step, state.step);
        assert_eq!(restored.entities.data, state.entities.data);
        assert_eq!(restored.entities.m, state.entities.m);
        assert_eq!(restored.relations.v, state.relations.v);
        assert_eq!(
            store.generations().len() as u64,
            steps as u64,
            "every step committed a generation"
        );
        let mut fresh = CheckpointStore::open(&dir);
        assert_eq!(
            fresh.next_kind(&restored),
            SaveKind::Full,
            "a cold store has no delta anchor"
        );
        fresh.save(&restored).unwrap();
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn phase_attribution_covers_the_full_pipeline() {
        let (rt, kg, cfg) = setup(Batching::OperatorLevel, Pipelining::Async);
        let mut state = mock_state(&rt, &kg);
        let r = Trainer::new(&rt, kg, cfg).train(&mut state).unwrap();
        for bucket in ["sample", "build_dag", "execute", "execute/gather", "optimize"] {
            assert!(
                r.phases.iter().any(|(n, _)| n == bucket),
                "missing phase bucket {bucket}: {:?}",
                r.phases
            );
        }
    }
}
