//! Tiny CLI argument parser (the offline registry has no clap).
//!
//! Grammar: `prog <subcommand> [--flag] [--key=value] [pos...]`. A bare
//! `--name` is always a boolean flag (no lookahead ambiguity); option values
//! require `=`. The exception is `--set k=v`, which may also be spelled
//! `--set=k=v`; repeated `--set` options accumulate (config overrides).

use std::collections::BTreeMap;

use anyhow::{bail, Result};

/// Parsed command line.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
    pub sets: Vec<(String, String)>,
}

impl Args {
    /// Parse from an explicit token list (tests) — first token is NOT argv[0].
    pub fn parse_tokens<I: IntoIterator<Item = String>>(tokens: I) -> Result<Args> {
        let mut args = Args::default();
        let mut it = tokens.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(name) = tok.strip_prefix("--") {
                if name.is_empty() {
                    bail!("bare -- not supported");
                }
                if let Some((k, v)) = name.split_once('=') {
                    args.record(k, v.to_string())?;
                } else if name == "set" {
                    let kv = it.next().ok_or_else(|| anyhow::anyhow!("--set needs k=v"))?;
                    let (k, v) =
                        kv.split_once('=').ok_or_else(|| anyhow::anyhow!("--set needs k=v"))?;
                    args.sets.push((k.to_string(), v.to_string()));
                } else {
                    args.flags.push(name.to_string());
                }
            } else if args.subcommand.is_none() && args.positional.is_empty() {
                args.subcommand = Some(tok);
            } else {
                args.positional.push(tok);
            }
        }
        Ok(args)
    }

    /// Parse the real process arguments.
    pub fn from_env() -> Result<Args> {
        Args::parse_tokens(std::env::args().skip(1))
    }

    fn record(&mut self, key: &str, value: String) -> Result<()> {
        if key == "set" {
            let (k, v) =
                value.split_once('=').ok_or_else(|| anyhow::anyhow!("--set needs k=v"))?;
            self.sets.push((k.to_string(), v.to_string()));
        } else {
            self.options.insert(key.to_string(), value);
        }
        Ok(())
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn opt(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(String::as_str)
    }

    pub fn str_or(&self, name: &str, default: &str) -> String {
        self.opt(name).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, name: &str, default: usize) -> Result<usize> {
        match self.opt(name) {
            Some(v) => Ok(v.parse()?),
            None => Ok(default),
        }
    }

    pub fn f64_or(&self, name: &str, default: f64) -> Result<f64> {
        match self.opt(name) {
            Some(v) => Ok(v.parse()?),
            None => Ok(default),
        }
    }

    pub fn u64_or(&self, name: &str, default: u64) -> Result<u64> {
        match self.opt(name) {
            Some(v) => Ok(v.parse()?),
            None => Ok(default),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toks(s: &str) -> Vec<String> {
        s.split_whitespace().map(str::to_string).collect()
    }

    #[test]
    fn parses_subcommand_options_flags() {
        let a = Args::parse_tokens(toks(
            "train --config=configs/fb15k.toml --steps=100 --verbose extra",
        ))
        .unwrap();
        assert_eq!(a.subcommand.as_deref(), Some("train"));
        assert_eq!(a.opt("config"), Some("configs/fb15k.toml"));
        assert_eq!(a.usize_or("steps", 0).unwrap(), 100);
        assert!(a.has_flag("verbose"));
        assert_eq!(a.positional, vec!["extra"]);
    }

    #[test]
    fn set_accumulates() {
        let a = Args::parse_tokens(toks("bench --set a.b=1 --set c=x")).unwrap();
        assert_eq!(a.sets.len(), 2);
        assert_eq!(a.sets[0], ("a.b".into(), "1".into()));
    }

    #[test]
    fn bare_dashes_are_flags() {
        let a = Args::parse_tokens(toks("run --dry --out=path")).unwrap();
        assert!(a.has_flag("dry"));
        assert_eq!(a.opt("out"), Some("path"));
    }

    #[test]
    fn set_with_equals_spelling() {
        let a = Args::parse_tokens(toks("x --set=a.b=2")).unwrap();
        assert_eq!(a.sets[0], ("a.b".into(), "2".into()));
    }

    #[test]
    fn errors_on_bad_set() {
        assert!(Args::parse_tokens(toks("x --set novalue")).is_err());
    }
}
