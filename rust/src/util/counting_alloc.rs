//! Test/bench-only counting global allocator — the measurement half of the
//! allocation-regression gate, mirroring how `exec::worker_spawns_total()`
//! anchors the zero-spawn gate.
//!
//! The library never installs this allocator; a test or bench binary opts
//! in with
//!
//! ```ignore
//! #[global_allocator]
//! static ALLOC: ngdb_zoo::util::counting_alloc::CountingAlloc = CountingAlloc;
//! ```
//!
//! after which [`snapshot`] / [`AllocSnapshot::delta_since`] measure heap
//! traffic across a region of interest. Counters are process-global
//! (allocations from *any* thread count, including the session's gather
//! worker — deliberately: speculative gathers are part of a round's cost),
//! so tests sharing a binary must serialize, the same discipline the
//! spawn-counter suites already use. When no binary installs the
//! allocator, the counters simply stay at zero and cost nothing.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

static ALLOCS: AtomicU64 = AtomicU64::new(0);
static FREES: AtomicU64 = AtomicU64::new(0);
static BYTES: AtomicU64 = AtomicU64::new(0);

/// Forwarding wrapper around [`System`] that counts every allocation and
/// its size. Counting uses relaxed atomics only — the allocator itself
/// never allocates.
pub struct CountingAlloc;

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        BYTES.fetch_add(layout.size() as u64, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        BYTES.fetch_add(layout.size() as u64, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        BYTES.fetch_add(new_size as u64, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        FREES.fetch_add(1, Ordering::Relaxed);
        System.dealloc(ptr, layout)
    }
}

/// Allocation counters at one instant.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct AllocSnapshot {
    /// `alloc` + `alloc_zeroed` + `realloc` calls
    pub allocs: u64,
    /// `dealloc` calls
    pub frees: u64,
    /// bytes requested across all allocating calls
    pub bytes: u64,
}

impl AllocSnapshot {
    /// Counter growth since `earlier`.
    pub fn delta_since(&self, earlier: &AllocSnapshot) -> AllocSnapshot {
        AllocSnapshot {
            allocs: self.allocs - earlier.allocs,
            frees: self.frees - earlier.frees,
            bytes: self.bytes - earlier.bytes,
        }
    }
}

/// Current process-wide counters (all zero unless a binary installed
/// [`CountingAlloc`] as its `#[global_allocator]`).
pub fn snapshot() -> AllocSnapshot {
    AllocSnapshot {
        allocs: ALLOCS.load(Ordering::Relaxed),
        frees: FREES.load(Ordering::Relaxed),
        bytes: BYTES.load(Ordering::Relaxed),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_delta_arithmetic() {
        let a = AllocSnapshot { allocs: 10, frees: 4, bytes: 1024 };
        let b = AllocSnapshot { allocs: 25, frees: 9, bytes: 4096 };
        assert_eq!(
            b.delta_since(&a),
            AllocSnapshot { allocs: 15, frees: 5, bytes: 3072 }
        );
    }
}
