//! Fault-injection points ("failpoints") for crash-safety testing.
//!
//! The checkpoint layer threads named sites through its write path
//! (`ckpt.write.tensor`, `ckpt.commit.rename`, ...); a test arms a site
//! with an [`Action`] and the next code path that [`check`]s it fails in a
//! controlled way:
//!
//! * [`Action::Error`] — the site reports an injected I/O error (the
//!   caller maps it into its own error type and unwinds normally);
//! * [`Action::ShortWrite`] — the site truncates the write in progress
//!   (the caller flushes the partial prefix to disk, then errors) — the
//!   torn-file case checksums must catch;
//! * [`Action::Abort`] — the process dies on the spot via
//!   [`std::process::abort`], no destructors, no flushes — the `kill -9`
//!   case the atomic-commit protocol must survive. Subprocess tests
//!   (`rust/tests/checkpoint_crash.rs`) arm this in a child process and
//!   assert the parent can always recover the previous generation.
//!
//! Sites are armed programmatically ([`set`]) or through the
//! `NGDB_FAILPOINTS` environment variable (read once, on first check):
//!
//! ```text
//! NGDB_FAILPOINTS="ckpt.commit.rename=abort;ckpt.write.tensor=error@3"
//! ```
//!
//! `site=action` fires on the first hit; `@N` delays to the N-th hit;
//! a trailing `*` (`site=error*`) fires on every hit until cleared. The
//! registry is a single process-global mutex-guarded map — this is test
//! scaffolding, not a hot path; an *unarmed* check is one mutex lock and
//! a hash probe, and the map is empty in production.

use std::collections::HashMap;
use std::sync::{Mutex, OnceLock};

/// What an armed site does when it fires.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Action {
    /// report an injected I/O error to the caller
    Error,
    /// truncate the write in progress, then report an error
    ShortWrite,
    /// kill the process immediately (no unwinding, no flushes)
    Abort,
}

/// When an armed site fires.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Trigger {
    /// fire on the N-th hit (1-based), then disarm
    Once(u64),
    /// fire on every hit until [`clear`]ed
    Always,
}

/// What [`check`] tells the caller to do. [`Action::Abort`] never returns.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Fired {
    Error,
    ShortWrite,
}

#[derive(Debug)]
struct Site {
    action: Action,
    trigger: Trigger,
    hits: u64,
}

fn registry() -> &'static Mutex<HashMap<String, Site>> {
    static REGISTRY: OnceLock<Mutex<HashMap<String, Site>>> = OnceLock::new();
    REGISTRY.get_or_init(|| {
        let mut map = HashMap::new();
        if let Ok(spec) = std::env::var("NGDB_FAILPOINTS") {
            for (name, site) in parse_env(&spec) {
                map.insert(name, site);
            }
        }
        Mutex::new(map)
    })
}

fn parse_env(spec: &str) -> Vec<(String, Site)> {
    let mut out = Vec::new();
    for entry in spec.split([';', ',']).map(str::trim).filter(|e| !e.is_empty()) {
        let Some((name, rhs)) = entry.split_once('=') else {
            eprintln!("failpoint: ignoring malformed NGDB_FAILPOINTS entry {entry:?}");
            continue;
        };
        let (rhs, always) = match rhs.strip_suffix('*') {
            Some(r) => (r, true),
            None => (rhs, false),
        };
        let (action_str, nth) = match rhs.split_once('@') {
            Some((a, n)) => (a, n.parse::<u64>().unwrap_or(1).max(1)),
            None => (rhs, 1),
        };
        let action = match action_str {
            "error" => Action::Error,
            "shortwrite" | "short-write" => Action::ShortWrite,
            "abort" => Action::Abort,
            other => {
                eprintln!("failpoint: unknown action {other:?} in NGDB_FAILPOINTS");
                continue;
            }
        };
        let trigger = if always { Trigger::Always } else { Trigger::Once(nth) };
        out.push((name.trim().to_string(), Site { action, trigger, hits: 0 }));
    }
    out
}

/// Arm `name` with `action` under `trigger` (replaces any prior arming;
/// hit counts restart at zero).
pub fn set(name: &str, action: Action, trigger: Trigger) {
    registry()
        .lock()
        .unwrap_or_else(|e| e.into_inner())
        .insert(name.to_string(), Site { action, trigger, hits: 0 });
}

/// Disarm `name` (a no-op if it was never armed).
pub fn clear(name: &str) {
    registry().lock().unwrap_or_else(|e| e.into_inner()).remove(name);
}

/// Disarm every site and reset all hit counts.
pub fn clear_all() {
    registry().lock().unwrap_or_else(|e| e.into_inner()).clear();
}

/// Total hits recorded against `name` since it was (last) armed.
pub fn hits(name: &str) -> u64 {
    registry()
        .lock()
        .unwrap_or_else(|e| e.into_inner())
        .get(name)
        .map_or(0, |s| s.hits)
}

/// The instrumented code path calls this at each named site. Returns
/// `None` (keep going) unless the site is armed and due, in which case the
/// caller gets [`Fired::Error`] / [`Fired::ShortWrite`] — or, for
/// [`Action::Abort`], the process dies right here.
pub fn check(name: &str) -> Option<Fired> {
    let mut map = registry().lock().unwrap_or_else(|e| e.into_inner());
    let site = map.get_mut(name)?;
    site.hits += 1;
    let due = match site.trigger {
        Trigger::Once(nth) => site.hits == nth,
        Trigger::Always => true,
    };
    if !due {
        return None;
    }
    let action = site.action;
    if matches!(site.trigger, Trigger::Once(_)) {
        map.remove(name);
    }
    drop(map); // don't poison/hold the registry across an abort
    match action {
        Action::Error => Some(Fired::Error),
        Action::ShortWrite => Some(Fired::ShortWrite),
        Action::Abort => {
            eprintln!("failpoint: aborting at site {name:?}");
            std::process::abort();
        }
    }
}

/// Injected-error constructor, so every site reports a recognizable kind.
pub fn injected_io_error(site: &str) -> std::io::Error {
    std::io::Error::new(
        std::io::ErrorKind::Other,
        format!("injected failpoint error at {site}"),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    // each test uses its own site names: the registry is process-global
    // and the test harness runs threads in parallel

    #[test]
    fn unarmed_sites_never_fire() {
        assert_eq!(check("test.fp.unarmed"), None);
        assert_eq!(hits("test.fp.unarmed"), 0);
    }

    #[test]
    fn once_fires_on_the_nth_hit_then_disarms() {
        set("test.fp.nth", Action::Error, Trigger::Once(3));
        assert_eq!(check("test.fp.nth"), None);
        assert_eq!(check("test.fp.nth"), None);
        assert_eq!(check("test.fp.nth"), Some(Fired::Error));
        assert_eq!(check("test.fp.nth"), None, "one-shot must disarm");
    }

    #[test]
    fn always_fires_until_cleared() {
        set("test.fp.always", Action::ShortWrite, Trigger::Always);
        assert_eq!(check("test.fp.always"), Some(Fired::ShortWrite));
        assert_eq!(check("test.fp.always"), Some(Fired::ShortWrite));
        clear("test.fp.always");
        assert_eq!(check("test.fp.always"), None);
    }

    #[test]
    fn env_spec_parses_actions_counts_and_always() {
        let sites = parse_env("a=error; b=shortwrite@4, c=abort, d=error*, junk, e=wat");
        let by_name: std::collections::HashMap<_, _> =
            sites.into_iter().map(|(n, s)| (n, s)).collect();
        assert_eq!(by_name["a"].action, Action::Error);
        assert_eq!(by_name["a"].trigger, Trigger::Once(1));
        assert_eq!(by_name["b"].action, Action::ShortWrite);
        assert_eq!(by_name["b"].trigger, Trigger::Once(4));
        assert_eq!(by_name["c"].action, Action::Abort);
        assert_eq!(by_name["d"].trigger, Trigger::Always);
        assert!(!by_name.contains_key("junk"));
        assert!(!by_name.contains_key("e"));
    }
}
