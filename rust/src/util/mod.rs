//! Small self-contained utilities: RNG, CLI parsing, JSON/TOML parsers,
//! statistics, timers, and the in-repo property-testing harness.
//!
//! These exist because the offline crate registry only carries the `xla`
//! dependency closure — see DESIGN.md §Substitutions.

pub mod cli;
pub mod counting_alloc;
pub mod failpoint;
pub mod json;
pub mod proptest;
pub mod rng;
pub mod stats;
pub mod timer;
pub mod toml;
