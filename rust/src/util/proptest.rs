//! In-repo property-testing harness (offline registry has no proptest).
//!
//! Usage:
//! ```ignore
//! prop_check("refcounts reach zero", 200, |rng| {
//!     let dag = gen_random_dag(rng, 1..40);
//!     run_and_assert_invariants(&dag)  // -> Result<(), String>
//! });
//! ```
//! Each case gets a derived seed; on failure the harness reports the exact
//! seed so the case replays deterministically with `NGDB_PROP_SEED`.

use super::rng::Rng;

/// Number of cases multiplier via env (CI can crank it up).
fn case_multiplier() -> usize {
    std::env::var("NGDB_PROP_MULT").ok().and_then(|v| v.parse().ok()).unwrap_or(1)
}

/// Run `cases` generative checks of `f`; panics (test failure) with the
/// failing seed on the first counterexample.
pub fn prop_check<F>(name: &str, cases: usize, mut f: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    let base: u64 = std::env::var("NGDB_PROP_SEED")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(0xA11CE);
    let cases = cases * case_multiplier();
    for case in 0..cases {
        let seed = base.wrapping_add(case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::new(seed);
        if let Err(msg) = f(&mut rng) {
            panic!(
                "property {name:?} failed on case {case}/{cases} \
                 (replay: NGDB_PROP_SEED={} case offset {case}):\n{msg}",
                base
            );
        }
    }
}

/// Generator helpers.
pub mod gen {
    use super::Rng;

    /// Random length in `[lo, hi]`, biased toward small values.
    pub fn size(rng: &mut Rng, lo: usize, hi: usize) -> usize {
        debug_assert!(lo <= hi);
        // square-bias toward the small end: small cases shrink "for free"
        let u = rng.f64();
        lo + ((u * u) * (hi - lo + 1) as f64) as usize
    }

    /// Vector of f32s in [-scale, scale].
    pub fn f32_vec(rng: &mut Rng, len: usize, scale: f32) -> Vec<f32> {
        (0..len).map(|_| rng.uniform_sym(scale)).collect()
    }

    /// Random subset of 0..n (possibly empty).
    pub fn subset(rng: &mut Rng, n: usize, p: f64) -> Vec<usize> {
        (0..n).filter(|_| rng.chance(p)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        prop_check("reverse twice is identity", 50, |rng| {
            let n = gen::size(rng, 0, 20);
            let v: Vec<u64> = (0..n).map(|_| rng.next_u64()).collect();
            let mut w = v.clone();
            w.reverse();
            w.reverse();
            if v == w {
                Ok(())
            } else {
                Err("mismatch".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property")]
    fn reports_failures() {
        prop_check("always fails", 3, |_| Err("nope".into()));
    }

    #[test]
    fn size_respects_bounds() {
        let mut rng = Rng::new(1);
        for _ in 0..200 {
            let s = gen::size(&mut rng, 2, 9);
            assert!((2..=9).contains(&s));
        }
    }
}
