//! In-repo property-testing harness (offline registry has no proptest).
//!
//! Usage:
//! ```ignore
//! prop_check("refcounts reach zero", 200, |rng| {
//!     let dag = gen_random_dag(rng, 1..40);
//!     run_and_assert_invariants(&dag)  // -> Result<(), String>
//! });
//! ```
//! Each case gets a derived seed; on failure the harness reports the exact
//! seed so the case replays deterministically with `NGDB_PROP_SEED`.

use super::rng::Rng;

/// Number of cases multiplier via env (CI can crank it up).
fn case_multiplier() -> usize {
    std::env::var("NGDB_PROP_MULT").ok().and_then(|v| v.parse().ok()).unwrap_or(1)
}

fn base_seed() -> u64 {
    std::env::var("NGDB_PROP_SEED").ok().and_then(|v| v.parse().ok()).unwrap_or(0xA11CE)
}

fn case_seed(base: u64, case: usize) -> u64 {
    base.wrapping_add(case as u64).wrapping_mul(0x9E3779B97F4A7C15)
}

/// Run `cases` generative checks of `f`; panics (test failure) with the
/// failing seed on the first counterexample.
pub fn prop_check<F>(name: &str, cases: usize, mut f: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    let base = base_seed();
    let cases = cases * case_multiplier();
    for case in 0..cases {
        let mut rng = Rng::new(case_seed(base, case));
        if let Err(msg) = f(&mut rng) {
            panic!(
                "property {name:?} failed on case {case}/{cases} \
                 (replay: NGDB_PROP_SEED={} case offset {case}):\n{msg}",
                base
            );
        }
    }
}

/// Cap on greedy shrink iterations (each iteration re-runs `check` on every
/// candidate, so the worst case is `SHRINK_BUDGET * max-candidates` runs).
const SHRINK_BUDGET: usize = 200;

/// Like [`prop_check`], but with generation split from checking so failing
/// values can be **shrunk**: on a counterexample the harness greedily walks
/// `shrink` candidates (re-checking each) to a local minimum before
/// reporting, so the panic message carries the smallest failing value it
/// could find instead of the raw random one.
pub fn prop_check_shrink<T, G, S, C>(name: &str, cases: usize, mut generate: G, shrink: S, check: C)
where
    T: std::fmt::Debug,
    G: FnMut(&mut Rng) -> T,
    S: Fn(&T) -> Vec<T>,
    C: Fn(&T) -> Result<(), String>,
{
    let base = base_seed();
    let cases = cases * case_multiplier();
    for case in 0..cases {
        let mut rng = Rng::new(case_seed(base, case));
        let value = generate(&mut rng);
        let Err(msg) = check(&value) else { continue };

        // greedy descent: take the first failing shrink candidate, repeat
        let (mut cur, mut cur_msg, mut steps) = (value, msg, 0usize);
        'descend: while steps < SHRINK_BUDGET {
            for cand in shrink(&cur) {
                if let Err(m) = check(&cand) {
                    cur = cand;
                    cur_msg = m;
                    steps += 1;
                    continue 'descend;
                }
            }
            break; // local minimum: every candidate passes
        }
        panic!(
            "property {name:?} failed on case {case}/{cases} \
             (replay: NGDB_PROP_SEED={base} case offset {case}); \
             shrunk {steps} steps to minimal counterexample:\n{cur:#?}\n{cur_msg}"
        );
    }
}

/// Shared random-workload generator for engine/scheduler property tests:
/// grounded query mixtures over the toy graph, remapped into small
/// embedding tables, with [`QuerySet::shrink`] candidates for
/// [`prop_check_shrink`]. One generator, reused by the in-crate engine
/// tests, `rust/tests/proptests.rs`, and the scheduler-equivalence suite —
/// instead of three ad-hoc copies.
pub mod queries {
    use super::gen;
    use super::Rng;
    use crate::kg::{KgSpec, KgStore};
    use crate::query::{Pattern, QueryDag, QueryTree};
    use crate::sampler::ground;

    /// One grounded training query (ids already remapped into the target
    /// vocabulary sizes).
    #[derive(Clone, Debug)]
    pub struct QuerySpec {
        pub pattern: Pattern,
        pub tree: QueryTree,
        pub answer: u32,
        pub negatives: Vec<u32>,
    }

    /// A shrinkable random workload.
    #[derive(Clone, Debug)]
    pub struct QuerySet(pub Vec<QuerySpec>);

    /// The small deterministic graph every engine property test samples
    /// from.
    pub fn toy_kg() -> KgStore {
        KgSpec::preset("toy", 1.0).unwrap().generate().unwrap()
    }

    /// Remap every entity/relation id into `[0, ne)` / `[0, nr)` so trees
    /// grounded on an arbitrary graph index small test embedding tables.
    pub fn remap_tree(tree: &QueryTree, ne: u32, nr: u32) -> QueryTree {
        match tree {
            QueryTree::Anchor(e) => QueryTree::Anchor(e % ne),
            QueryTree::Project(c, r) => {
                QueryTree::Project(Box::new(remap_tree(c, ne, nr)), r % nr)
            }
            QueryTree::Intersect(cs) => {
                QueryTree::Intersect(cs.iter().map(|c| remap_tree(c, ne, nr)).collect())
            }
            QueryTree::Union(cs) => {
                QueryTree::Union(cs.iter().map(|c| remap_tree(c, ne, nr)).collect())
            }
            QueryTree::Negate(c) => QueryTree::Negate(Box::new(remap_tree(c, ne, nr))),
        }
    }

    /// Up to `max_q` grounded queries over `kg` drawn from `patterns`,
    /// remapped into `ne`/`nr`-sized tables, each with `n_neg` random
    /// negatives. May return fewer queries (grounding can fail) — callers
    /// should skip empty sets.
    pub fn random_set(
        rng: &mut Rng,
        kg: &KgStore,
        patterns: &[Pattern],
        max_q: usize,
        ne: u32,
        nr: u32,
        n_neg: usize,
    ) -> QuerySet {
        let n_q = gen::size(rng, 1, max_q);
        let mut specs = Vec::new();
        for _ in 0..n_q {
            let p = *rng.choice(patterns);
            if let Some(g) = ground(kg, rng, p) {
                specs.push(QuerySpec {
                    pattern: p,
                    tree: remap_tree(&g.tree, ne, nr),
                    answer: g.answer % ne,
                    negatives: (0..n_neg).map(|_| rng.below(ne as usize) as u32).collect(),
                });
            }
        }
        QuerySet(specs)
    }

    impl QuerySet {
        pub fn is_empty(&self) -> bool {
            self.0.is_empty()
        }

        pub fn len(&self) -> usize {
            self.0.len()
        }

        /// Lower the workload into one fused training DAG (gradient nodes
        /// appended).
        pub fn train_dag(&self) -> QueryDag {
            let mut dag = QueryDag::default();
            for q in &self.0 {
                dag.add_query(&q.tree, q.answer, q.negatives.clone(), q.pattern.name(), true)
                    .expect("generated query must lower");
            }
            dag.add_gradient_nodes();
            dag
        }

        /// Lower the workload onto the **forward plane** (the eval/serve
        /// path): no answers, no negatives, no gradient nodes. Returns the
        /// fused DAG plus one root per query, in workload order — feed
        /// them to `EngineSession::run_forward`.
        pub fn forward_dag(&self, supports_negation: bool) -> (QueryDag, Vec<u32>) {
            let mut dag = QueryDag::default();
            let mut roots = Vec::with_capacity(self.0.len());
            for q in &self.0 {
                roots.push(
                    dag.add_query_eval(&q.tree, supports_negation)
                        .expect("generated query must lower"),
                );
            }
            (dag, roots)
        }

        /// Shrink candidates, biggest cuts first: the two halves, then each
        /// drop-one subset (only for small sets — drop-one on a large set
        /// explodes the candidate count without shrinking much).
        pub fn shrink(&self) -> Vec<QuerySet> {
            let n = self.0.len();
            if n <= 1 {
                return Vec::new();
            }
            let mut out = Vec::new();
            out.push(QuerySet(self.0[..n / 2].to_vec()));
            out.push(QuerySet(self.0[n / 2..].to_vec()));
            if n <= 12 {
                for i in 0..n {
                    let mut v = self.0.clone();
                    v.remove(i);
                    out.push(QuerySet(v));
                }
            }
            out
        }
    }
}

/// Generator helpers.
pub mod gen {
    use super::Rng;

    /// Random length in `[lo, hi]`, biased toward small values.
    pub fn size(rng: &mut Rng, lo: usize, hi: usize) -> usize {
        debug_assert!(lo <= hi);
        // square-bias toward the small end: small cases shrink "for free"
        let u = rng.f64();
        lo + ((u * u) * (hi - lo + 1) as f64) as usize
    }

    /// Vector of f32s in [-scale, scale].
    pub fn f32_vec(rng: &mut Rng, len: usize, scale: f32) -> Vec<f32> {
        (0..len).map(|_| rng.uniform_sym(scale)).collect()
    }

    /// Random subset of 0..n (possibly empty).
    pub fn subset(rng: &mut Rng, n: usize, p: f64) -> Vec<usize> {
        (0..n).filter(|_| rng.chance(p)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        prop_check("reverse twice is identity", 50, |rng| {
            let n = gen::size(rng, 0, 20);
            let v: Vec<u64> = (0..n).map(|_| rng.next_u64()).collect();
            let mut w = v.clone();
            w.reverse();
            w.reverse();
            if v == w {
                Ok(())
            } else {
                Err("mismatch".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property")]
    fn reports_failures() {
        prop_check("always fails", 3, |_| Err("nope".into()));
    }

    #[test]
    fn size_respects_bounds() {
        let mut rng = Rng::new(1);
        for _ in 0..200 {
            let s = gen::size(&mut rng, 2, 9);
            assert!((2..=9).contains(&s));
        }
    }
}
