//! Deterministic, dependency-free RNG (SplitMix64 seeding + xoshiro256**).
//!
//! Every stochastic component of the coordinator (graph generation, query
//! sampling, negative sampling, initialization) threads one of these through
//! so that entire experiments replay bit-identically from a single seed.

/// xoshiro256** by Blackman & Vigna — fast, high-quality, 2^256-1 period.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    /// cached second normal from Box-Muller
    spare: Option<f64>,
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed the generator; any u64 is fine (SplitMix64 whitens it).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng { s: core::array::from_fn(|_| splitmix64(&mut sm)), spare: None }
    }

    /// Derive an independent stream (e.g. one per worker thread).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0x9E3779B97F4A7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, n)` (Lemire's multiply-shift; unbiased for our sizes).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo)
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform f32 in `[-a, a]`.
    #[inline]
    pub fn uniform_sym(&mut self, a: f32) -> f32 {
        (self.f64() * 2.0 - 1.0) as f32 * a
    }

    /// Standard normal (Box-Muller, cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare.take() {
            return z;
        }
        loop {
            let u = self.f64();
            if u <= f64::EPSILON {
                continue;
            }
            let v = self.f64();
            let r = (-2.0 * u.ln()).sqrt();
            let (s, c) = (std::f64::consts::TAU * v).sin_cos();
            self.spare = Some(r * s);
            return r * c;
        }
    }

    /// Bernoulli with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Pick a uniform element of a non-empty slice.
    #[inline]
    pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            xs.swap(i, self.below(i + 1));
        }
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        debug_assert!(total > 0.0, "weighted() needs positive mass");
        let mut t = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            t -= w;
            if t <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

/// Cumulative-sum sampler for repeated draws from a fixed distribution.
/// O(log n) per draw — used for degree-weighted edge sampling at scale.
#[derive(Debug, Clone)]
pub struct CumSampler {
    cum: Vec<f64>,
}

impl CumSampler {
    pub fn new(weights: impl Iterator<Item = f64>) -> Self {
        let mut cum = Vec::new();
        let mut acc = 0.0;
        for w in weights {
            acc += w.max(0.0);
            cum.push(acc);
        }
        assert!(acc > 0.0, "CumSampler needs positive total mass");
        CumSampler { cum }
    }

    pub fn sample(&self, rng: &mut Rng) -> usize {
        let t = rng.f64() * self.cum.last().copied().unwrap_or(1.0);
        self.cum.partition_point(|&c| c < t).min(self.cum.len() - 1)
    }

    pub fn len(&self) -> usize {
        self.cum.len()
    }

    pub fn is_empty(&self) -> bool {
        self.cum.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_construction() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn forks_are_independent() {
        let mut root = Rng::new(1);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn below_in_bounds_and_covers() {
        let mut r = Rng::new(3);
        let mut seen = [false; 7];
        for _ in 0..500 {
            let v = r.below(7);
            assert!(v < 7);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(9);
        let n = 20_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let z = r.normal();
            sum += z;
            sq += z * z;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.08, "var {var}");
    }

    #[test]
    fn weighted_respects_mass() {
        let mut r = Rng::new(5);
        let w = [1.0, 0.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..4000 {
            counts[r.weighted(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        assert!(counts[2] > counts[0] * 2);
    }

    #[test]
    fn cum_sampler_matches_weighted() {
        let mut r = Rng::new(6);
        let s = CumSampler::new([2.0, 0.0, 1.0].into_iter());
        let mut counts = [0usize; 3];
        for _ in 0..3000 {
            counts[s.sample(&mut r)] += 1;
        }
        assert_eq!(counts[1], 0);
        assert!(counts[0] > counts[2]);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(8);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
