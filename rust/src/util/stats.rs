//! Small statistics helpers used by metrics and the benchkit harness.

/// Mean of a sample (0.0 for empty input).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// NaN policy shared by [`percentile`] / [`percentiles`]: NaNs carry no
/// rank, so they are dropped from the sample before sorting (sorted-last
/// values excluded from interpolation — a NaN must never interpolate into
/// a finite percentile, and `partial_cmp().unwrap()` must never panic a
/// metrics path). Returns the cleaned, ascending sample.
fn sorted_clean(xs: &[f64]) -> Vec<f64> {
    let mut v: Vec<f64> = xs.iter().copied().filter(|x| !x.is_nan()).collect();
    v.sort_by(f64::total_cmp);
    v
}

/// Interpolated percentile over an already-cleaned ascending sample.
fn of_sorted(v: &[f64], p: f64) -> f64 {
    if v.is_empty() {
        return 0.0;
    }
    let rank = (p.clamp(0.0, 100.0) / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (rank - lo as f64) * (v[hi] - v[lo])
    }
}

/// Interpolated percentile, `p` clamped to [0, 100]. NaN samples are
/// excluded (an all-NaN or empty sample yields 0.0).
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    of_sorted(&sorted_clean(xs), p)
}

/// Several percentiles from ONE sort of the sample — use this instead of
/// calling [`percentile`] once per quantile (each call clones + re-sorts;
/// the serve benches read p50/p95/p99 off every latency set). Same NaN /
/// empty / clamping semantics as [`percentile`].
pub fn percentiles(xs: &[f64], ps: &[f64]) -> Vec<f64> {
    let v = sorted_clean(xs);
    ps.iter().map(|&p| of_sorted(&v, p)).collect()
}

/// Median.
pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Median absolute deviation (robust spread).
pub fn mad(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let m = median(xs);
    let dev: Vec<f64> = xs.iter().map(|x| (x - m).abs()).collect();
    median(&dev)
}

/// Pretty-print a byte count.
pub fn fmt_bytes(b: usize) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = b as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{b} B")
    } else {
        format!("{v:.2} {}", UNITS[u])
    }
}

/// Pretty-print a duration in seconds adaptively.
pub fn fmt_secs(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1} ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2} µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2} ms", s * 1e3)
    } else {
        format!("{s:.2} s")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_moments() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((mean(&xs) - 2.5).abs() < 1e-12);
        assert!((median(&xs) - 2.5).abs() < 1e-12);
        assert!((percentile(&xs, 0.0) - 1.0).abs() < 1e-12);
        assert!((percentile(&xs, 100.0) - 4.0).abs() < 1e-12);
    }

    #[test]
    fn mad_is_robust_to_outlier() {
        let xs = [1.0, 1.1, 0.9, 1.0, 100.0];
        assert!(mad(&xs) < 0.2);
        assert!(stddev(&xs) > 10.0);
    }

    #[test]
    fn formatting() {
        assert_eq!(fmt_bytes(512), "512 B");
        assert!(fmt_bytes(1536).starts_with("1.50 KiB"));
        assert!(fmt_secs(0.0025).contains("ms"));
        assert!(fmt_secs(2.0).contains("s"));
    }

    #[test]
    fn empty_inputs_are_zero() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(median(&[]), 0.0);
        assert_eq!(mad(&[]), 0.0);
    }

    #[test]
    fn percentile_ignores_nans_instead_of_panicking() {
        // the seed's partial_cmp().unwrap() panicked on any NaN sample
        let xs = [3.0, f64::NAN, 1.0, 2.0, f64::NAN];
        assert!((percentile(&xs, 50.0) - 2.0).abs() < 1e-12);
        assert!((percentile(&xs, 0.0) - 1.0).abs() < 1e-12);
        assert!((percentile(&xs, 100.0) - 3.0).abs() < 1e-12);
        // NaNs never interpolate into the result
        assert!(percentile(&xs, 99.0).is_finite());
        // all-NaN behaves like empty
        assert_eq!(percentile(&[f64::NAN, f64::NAN], 50.0), 0.0);
        // median/mad ride the same path
        assert!((median(&xs) - 2.0).abs() < 1e-12);
        assert!(mad(&xs).is_finite());
    }

    #[test]
    fn percentile_single_element_and_clamped_p() {
        assert_eq!(percentile(&[7.5], 0.0), 7.5);
        assert_eq!(percentile(&[7.5], 50.0), 7.5);
        assert_eq!(percentile(&[7.5], 100.0), 7.5);
        // out-of-range p clamps instead of indexing out of bounds
        assert_eq!(percentile(&[1.0, 2.0], 150.0), 2.0);
        assert_eq!(percentile(&[1.0, 2.0], -5.0), 1.0);
    }

    #[test]
    fn percentiles_matches_per_call_percentile_with_one_sort() {
        let xs = [5.0, 1.0, 4.0, 2.0, 3.0, f64::NAN];
        let ps = [0.0, 25.0, 50.0, 95.0, 99.0, 100.0];
        let batch = percentiles(&xs, &ps);
        assert_eq!(batch.len(), ps.len());
        for (&p, &got) in ps.iter().zip(&batch) {
            assert_eq!(got.to_bits(), percentile(&xs, p).to_bits());
        }
        assert!(percentiles(&[], &[50.0]) == vec![0.0]);
        assert!(percentiles(&xs, &[]).is_empty());
    }
}
