//! Small statistics helpers used by metrics and the benchkit harness.

/// Mean of a sample (0.0 for empty input).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Interpolated percentile, `p` in [0, 100].
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (rank - lo as f64) * (v[hi] - v[lo])
    }
}

/// Median.
pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Median absolute deviation (robust spread).
pub fn mad(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let m = median(xs);
    let dev: Vec<f64> = xs.iter().map(|x| (x - m).abs()).collect();
    median(&dev)
}

/// Pretty-print a byte count.
pub fn fmt_bytes(b: usize) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = b as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{b} B")
    } else {
        format!("{v:.2} {}", UNITS[u])
    }
}

/// Pretty-print a duration in seconds adaptively.
pub fn fmt_secs(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1} ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2} µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2} ms", s * 1e3)
    } else {
        format!("{s:.2} s")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_moments() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((mean(&xs) - 2.5).abs() < 1e-12);
        assert!((median(&xs) - 2.5).abs() < 1e-12);
        assert!((percentile(&xs, 0.0) - 1.0).abs() < 1e-12);
        assert!((percentile(&xs, 100.0) - 4.0).abs() < 1e-12);
    }

    #[test]
    fn mad_is_robust_to_outlier() {
        let xs = [1.0, 1.1, 0.9, 1.0, 100.0];
        assert!(mad(&xs) < 0.2);
        assert!(stddev(&xs) > 10.0);
    }

    #[test]
    fn formatting() {
        assert_eq!(fmt_bytes(512), "512 B");
        assert!(fmt_bytes(1536).starts_with("1.50 KiB"));
        assert!(fmt_secs(0.0025).contains("ms"));
        assert!(fmt_secs(2.0).contains("s"));
    }

    #[test]
    fn empty_inputs_are_zero() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(median(&[]), 0.0);
        assert_eq!(mad(&[]), 0.0);
    }
}
