//! Wall-clock timing helpers.

use std::time::Instant;

/// A simple stopwatch.
#[derive(Debug, Clone)]
pub struct Stopwatch {
    start: Instant,
}

impl Default for Stopwatch {
    fn default() -> Self {
        Self::new()
    }
}

impl Stopwatch {
    pub fn new() -> Self {
        Stopwatch { start: Instant::now() }
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn restart(&mut self) -> f64 {
        let e = self.elapsed_secs();
        self.start = Instant::now();
        e
    }
}

/// Accumulates named time buckets — used to attribute a training step's
/// wall-clock to sample/coalesce/execute/scatter/optimize phases.
#[derive(Debug, Default, Clone)]
pub struct PhaseTimer {
    pub buckets: Vec<(String, f64)>,
}

impl PhaseTimer {
    pub fn add(&mut self, name: &str, secs: f64) {
        if let Some(b) = self.buckets.iter_mut().find(|(n, _)| n == name) {
            b.1 += secs;
        } else {
            self.buckets.push((name.to_string(), secs));
        }
    }

    /// Time a closure into the named bucket.
    pub fn time<T>(&mut self, name: &str, f: impl FnOnce() -> T) -> T {
        let t = Instant::now();
        let out = f();
        self.add(name, t.elapsed().as_secs_f64());
        out
    }

    pub fn total(&self) -> f64 {
        self.buckets.iter().map(|(_, s)| s).sum()
    }

    pub fn report(&self) -> String {
        report_of(&self.buckets)
    }
}

/// Render a phase-attribution bucket list (largest first, with percent of
/// total) — shared by [`PhaseTimer::report`] and report structs that carry
/// their buckets as a plain `Vec<(String, f64)>` (trainer / multi-worker
/// reports).
pub fn report_of(buckets: &[(String, f64)]) -> String {
    let total: f64 = buckets.iter().map(|(_, s)| s).sum::<f64>().max(1e-12);
    let mut rows: Vec<_> = buckets.to_vec();
    // total_cmp: a NaN bucket (e.g. a 0/0 rate upstream) sorts
    // deterministically instead of panicking the report path
    rows.sort_by(|a, b| b.1.total_cmp(&a.1));
    rows.iter()
        .map(|(n, s)| format!("{n}: {} ({:.1}%)", super::stats::fmt_secs(*s), 100.0 * s / total))
        .collect::<Vec<_>>()
        .join(", ")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stopwatch_monotonic() {
        let sw = Stopwatch::new();
        let a = sw.elapsed_secs();
        let b = sw.elapsed_secs();
        assert!(b >= a);
    }

    #[test]
    fn phase_timer_accumulates() {
        let mut t = PhaseTimer::default();
        t.add("x", 1.0);
        t.add("x", 0.5);
        t.add("y", 0.25);
        assert!((t.total() - 1.75).abs() < 1e-12);
        assert!(t.report().starts_with("x:"));
    }

    #[test]
    fn report_of_survives_nan_buckets() {
        // the seed's partial_cmp().unwrap() panicked here; a NaN bucket
        // must render (deterministically ordered), not take down a report
        let buckets = vec![("ok".to_string(), 1.0), ("bad".to_string(), f64::NAN)];
        let r = report_of(&buckets);
        assert!(r.contains("ok:") && r.contains("bad:"));
        assert_eq!(report_of(&buckets), report_of(&buckets), "deterministic order");
    }

    #[test]
    fn report_of_matches_the_timer_report() {
        let mut t = PhaseTimer::default();
        t.add("a", 2.0);
        t.add("b", 1.0);
        assert_eq!(t.report(), report_of(&t.buckets));
        assert!(report_of(&t.buckets).starts_with("a:"));
    }
}
