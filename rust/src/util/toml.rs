//! TOML-subset parser for experiment config files (`configs/*.toml`).
//!
//! Supported: `[section]` / `[section.sub]` headers, `key = value` with
//! strings, integers, floats, booleans and flat arrays, plus `#` comments.
//! Keys flatten to dotted paths (`section.key`). This covers everything the
//! config system uses; it is not a general TOML implementation.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

/// A scalar config value.
#[derive(Debug, Clone, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Arr(Vec<TomlValue>),
}

impl TomlValue {
    pub fn as_str(&self) -> Result<&str> {
        match self {
            TomlValue::Str(s) => Ok(s),
            _ => bail!("expected string, got {self:?}"),
        }
    }

    pub fn as_i64(&self) -> Result<i64> {
        match self {
            TomlValue::Int(i) => Ok(*i),
            _ => bail!("expected integer, got {self:?}"),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            TomlValue::Float(f) => Ok(*f),
            TomlValue::Int(i) => Ok(*i as f64),
            _ => bail!("expected float, got {self:?}"),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            TomlValue::Bool(b) => Ok(*b),
            _ => bail!("expected bool, got {self:?}"),
        }
    }
}

/// Flat map of dotted keys to values.
#[derive(Debug, Clone, Default)]
pub struct TomlDoc {
    pub values: BTreeMap<String, TomlValue>,
}

impl TomlDoc {
    pub fn parse(text: &str) -> Result<TomlDoc> {
        let mut doc = TomlDoc::default();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest
                    .strip_suffix(']')
                    .with_context(|| format!("line {}: bad section", lineno + 1))?;
                section = name.trim().to_string();
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .with_context(|| format!("line {}: expected key = value", lineno + 1))?;
            let key = if section.is_empty() {
                k.trim().to_string()
            } else {
                format!("{section}.{}", k.trim())
            };
            let value = parse_value(v.trim())
                .with_context(|| format!("line {}: bad value {v:?}", lineno + 1))?;
            doc.values.insert(key, value);
        }
        Ok(doc)
    }

    pub fn load(path: &str) -> Result<TomlDoc> {
        let text =
            std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
        TomlDoc::parse(&text)
    }

    /// Apply a `key=value` override (CLI `--set`); value re-parsed as TOML.
    pub fn set(&mut self, key: &str, value: &str) -> Result<()> {
        self.values.insert(key.to_string(), parse_value(value)?);
        Ok(())
    }

    pub fn get(&self, key: &str) -> Option<&TomlValue> {
        self.values.get(key)
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key)
            .and_then(|v| v.as_str().ok().map(str::to_string))
            .unwrap_or_else(|| default.to_string())
    }

    pub fn i64_or(&self, key: &str, default: i64) -> i64 {
        self.get(key).and_then(|v| v.as_i64().ok()).unwrap_or(default)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.as_f64().ok()).unwrap_or(default)
    }

    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        self.get(key).and_then(|v| v.as_bool().ok()).unwrap_or(default)
    }
}

fn strip_comment(line: &str) -> &str {
    // '#' inside quoted strings is respected.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<TomlValue> {
    let s = s.trim();
    if s.is_empty() {
        bail!("empty value");
    }
    if let Some(inner) = s.strip_prefix('"') {
        let inner = inner.strip_suffix('"').context("unterminated string")?;
        return Ok(TomlValue::Str(inner.replace("\\\"", "\"").replace("\\\\", "\\")));
    }
    if let Some(inner) = s.strip_prefix('[') {
        let inner = inner.strip_suffix(']').context("unterminated array")?;
        let mut items = Vec::new();
        if !inner.trim().is_empty() {
            for part in split_top_level(inner) {
                items.push(parse_value(part.trim())?);
            }
        }
        return Ok(TomlValue::Arr(items));
    }
    match s {
        "true" => return Ok(TomlValue::Bool(true)),
        "false" => return Ok(TomlValue::Bool(false)),
        _ => {}
    }
    if let Ok(i) = s.replace('_', "").parse::<i64>() {
        return Ok(TomlValue::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    // bare word — treat as string (lets `--set model=betae` work unquoted)
    Ok(TomlValue::Str(s.to_string()))
}

/// Split on commas not inside quotes (arrays are flat; no nesting needed).
fn split_top_level(s: &str) -> Vec<&str> {
    let mut out = Vec::new();
    let mut start = 0;
    let mut in_str = false;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            ',' if !in_str => {
                out.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    out.push(&s[start..]);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_types() {
        let doc = TomlDoc::parse(
            r#"
            # top comment
            name = "fb15k"            # trailing comment
            [train]
            steps = 1_000
            lr = 1e-4
            adaptive = true
            buckets = [16, 128, 512]
            tags = ["a", "b,c"]
            "#,
        )
        .unwrap();
        assert_eq!(doc.str_or("name", ""), "fb15k");
        assert_eq!(doc.i64_or("train.steps", 0), 1000);
        assert!((doc.f64_or("train.lr", 0.0) - 1e-4).abs() < 1e-12);
        assert!(doc.bool_or("train.adaptive", false));
        match doc.get("train.buckets").unwrap() {
            TomlValue::Arr(v) => assert_eq!(v.len(), 3),
            other => panic!("{other:?}"),
        }
        match doc.get("train.tags").unwrap() {
            TomlValue::Arr(v) => assert_eq!(v[1], TomlValue::Str("b,c".into())),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn set_overrides() {
        let mut doc = TomlDoc::parse("[a]\nx = 1\n").unwrap();
        doc.set("a.x", "2").unwrap();
        doc.set("a.name", "betae").unwrap();
        assert_eq!(doc.i64_or("a.x", 0), 2);
        assert_eq!(doc.str_or("a.name", ""), "betae");
    }

    #[test]
    fn rejects_malformed() {
        assert!(TomlDoc::parse("[oops\n").is_err());
        assert!(TomlDoc::parse("justakey\n").is_err());
        assert!(TomlDoc::parse("k = \"unterminated\n").is_err());
    }

    #[test]
    fn defaults_apply() {
        let doc = TomlDoc::parse("").unwrap();
        assert_eq!(doc.i64_or("missing", 7), 7);
        assert_eq!(doc.str_or("missing", "d"), "d");
    }
}
