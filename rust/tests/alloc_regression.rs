//! Allocation-regression suite: a warm [`EngineSession`]'s steady-state
//! rounds must stay within the documented heap-allocation budget
//! (`exec::arena::{ROUND_ALLOC_BUDGET, RUN_ALLOC_OVERHEAD,
//! ROUND_ALLOC_BYTES_BUDGET}`), the pool must actually recycle (zero
//! steady-state misses), `reset` must release and then re-warm, and error
//! paths must return their buffers instead of bleeding them.
//!
//! This binary installs the counting global allocator, so — like the
//! spawn-counter suites — every test serializes on one lock to keep the
//! process-global deltas attributable. Counters include the gather
//! worker's allocations (speculative gathers are part of a round's cost).

use std::sync::{Mutex, MutexGuard};

use ngdb_zoo::eval::rank::{EntityRanker, RANK_ALLOC_OVERHEAD, RANK_ALLOC_PER_EXEC};
use ngdb_zoo::exec::arena::{
    ROUND_ALLOC_BUDGET, ROUND_ALLOC_BYTES_BUDGET, RUN_ALLOC_OVERHEAD,
};
use ngdb_zoo::exec::{EngineConfig, EngineSession, Grads};
use ngdb_zoo::model::ModelState;
use ngdb_zoo::query::{Pattern, QueryDag, QueryTree};
use ngdb_zoo::runtime::{HostKernelConfig, MockRuntime, Runtime};
use ngdb_zoo::util::counting_alloc::{snapshot, CountingAlloc};

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

/// Process-global allocation counters: tests must not run concurrently.
static SERIAL: Mutex<()> = Mutex::new(());

fn serial() -> MutexGuard<'static, ()> {
    SERIAL.lock().unwrap_or_else(|e| e.into_inner())
}

const NE: usize = 12; // entity rows
const NR: usize = 6; // relation rows
const N_NEG: usize = 4; // must match the mock config below

/// Wide mock dims so tensor payloads dwarf bookkeeping: one un-recycled
/// staging block here is tens of KiB, far outside the per-round byte
/// budget — the test genuinely distinguishes pooled from unpooled.
fn wide_runtime() -> MockRuntime {
    MockRuntime::with_config(64, N_NEG, &[16, 64, 256])
}

fn state(rt: &MockRuntime) -> ModelState {
    ModelState::init(rt.manifest(), "mock", NE, NR, None, 3).unwrap()
}

/// Fixed mixed workload (embed / project / intersect / negate chains with
/// their VJP mirrors): deterministic schedule, deterministic allocation
/// counts.
fn workload() -> QueryDag {
    let mut dag = QueryDag::default();
    let negs: Vec<u32> = (0..N_NEG as u32).collect();
    for i in 0..8u32 {
        let tree = QueryTree::instantiate(Pattern::P1, &[i % NE as u32], &[i % NR as u32])
            .unwrap();
        dag.add_query(&tree, (i + 1) % NE as u32, negs.clone(), Pattern::P1.name(), true)
            .unwrap();
    }
    for i in 0..6u32 {
        let tree = QueryTree::instantiate(
            Pattern::P2,
            &[(i + 3) % NE as u32],
            &[i % NR as u32, (i + 1) % NR as u32],
        )
        .unwrap();
        dag.add_query(&tree, i % NE as u32, negs.clone(), Pattern::P2.name(), true)
            .unwrap();
    }
    for i in 0..6u32 {
        let tree = QueryTree::instantiate(
            Pattern::I2,
            &[i % NE as u32, (i + 5) % NE as u32],
            &[i % NR as u32, (i + 2) % NR as u32],
        )
        .unwrap();
        dag.add_query(&tree, (i + 2) % NE as u32, negs.clone(), Pattern::I2.name(), true)
            .unwrap();
    }
    for i in 0..4u32 {
        let tree = QueryTree::instantiate(
            Pattern::In2,
            &[i % NE as u32, (i + 1) % NE as u32],
            &[i % NR as u32, (i + 3) % NR as u32],
        )
        .unwrap();
        dag.add_query(&tree, (i + 4) % NE as u32, negs.clone(), Pattern::In2.name(), true)
            .unwrap();
    }
    dag.add_gradient_nodes();
    dag
}

#[test]
fn steady_state_rounds_stay_within_the_documented_alloc_budget() {
    let _guard = serial();
    let rt = wide_runtime();
    let st = state(&rt);
    let dag = workload();
    let mut session = EngineSession::new(&rt, EngineConfig::default());
    // one reused Grads so sparse-accumulator keys are warm like a real
    // training loop's per-step accumulation
    let mut grads = Grads::default();

    // warmup: populate pool shelves, slab capacity, scratch capacity
    let s0 = session.run(&dag, &st, &mut grads).unwrap();
    session.run(&dag, &st, &mut grads).unwrap();
    let rounds_per_run = s0.executions as u64;
    assert!(rounds_per_run > 0);

    const RUNS: u64 = 5;
    let base = snapshot();
    for _ in 0..RUNS {
        let stats = session.run(&dag, &st, &mut grads).unwrap();
        assert_eq!(stats.executions as u64, rounds_per_run, "schedule must be stable");
        assert_eq!(
            stats.pool_misses, 0,
            "steady-state rounds must be fully served by the pool"
        );
        assert!(stats.pool_hits > 0);
    }
    let d = snapshot().delta_since(&base);

    let alloc_budget = RUNS * (RUN_ALLOC_OVERHEAD + rounds_per_run * ROUND_ALLOC_BUDGET);
    assert!(
        d.allocs <= alloc_budget,
        "steady state allocated {} times over {} rounds ({} runs); budget {} \
         ({} per round + {} per run)",
        d.allocs,
        RUNS * rounds_per_run,
        RUNS,
        alloc_budget,
        ROUND_ALLOC_BUDGET,
        RUN_ALLOC_OVERHEAD
    );
    // byte form of the same gate: no tensor-sized allocations survive
    let bytes_budget =
        RUNS * rounds_per_run * ROUND_ALLOC_BYTES_BUDGET + RUNS * 64 * 1024;
    assert!(
        d.bytes <= bytes_budget,
        "steady state allocated {} bytes; budget {}",
        d.bytes,
        bytes_budget
    );
}

#[test]
fn threaded_kernel_pool_adds_zero_steady_state_allocations() {
    // The multi-threaded host-kernel path must ride the same budgets as
    // the serial path: after the worker pool spawns (warmup), dispatching
    // a kernel across threads is allocation-free — the job broadcast is a
    // Copy struct under a lock, the chunk cursor and partial buffers live
    // on the submitting stack. Identical budgets, zero slack added.
    let _guard = serial();
    let kcfg = HostKernelConfig { threads: 4, par_min_elems: 0, ..Default::default() };
    let rt = wide_runtime().with_kernel_config(kcfg);
    let st = state(&rt);
    let dag = workload();
    let mut session = EngineSession::new(&rt, EngineConfig::default());
    let mut grads = Grads::default();

    // warmup: pool shelves + the host-kernel worker threads (stacks,
    // handles) all land here, outside the measured window
    let s0 = session.run(&dag, &st, &mut grads).unwrap();
    session.run(&dag, &st, &mut grads).unwrap();
    let rounds_per_run = s0.executions as u64;
    assert!(rounds_per_run > 0);

    const RUNS: u64 = 5;
    let base = snapshot();
    for _ in 0..RUNS {
        let stats = session.run(&dag, &st, &mut grads).unwrap();
        assert_eq!(stats.executions as u64, rounds_per_run, "schedule must be stable");
        assert_eq!(stats.pool_misses, 0, "threaded rounds must still pool");
    }
    let d = snapshot().delta_since(&base);

    // the SAME budgets the serial suite enforces — threading adds nothing
    let alloc_budget = RUNS * (RUN_ALLOC_OVERHEAD + rounds_per_run * ROUND_ALLOC_BUDGET);
    assert!(
        d.allocs <= alloc_budget,
        "threaded kernels allocated {} times over {} rounds; serial budget {}",
        d.allocs,
        RUNS * rounds_per_run,
        alloc_budget
    );
    let bytes_budget =
        RUNS * rounds_per_run * ROUND_ALLOC_BYTES_BUDGET + RUNS * 64 * 1024;
    assert!(
        d.bytes <= bytes_budget,
        "threaded kernels allocated {} bytes; budget {}",
        d.bytes,
        bytes_budget
    );

    // and the numbers must not have moved a bit vs the serial path
    let serial_rt = wide_runtime();
    let serial_st = state(&serial_rt);
    let mut serial_session = EngineSession::new(&serial_rt, EngineConfig::default());
    let mut sg = Grads::default();
    let s_stats = serial_session.run(&dag, &serial_st, &mut sg).unwrap();
    let mut tg = Grads::default();
    let t_stats = session.run(&dag, &st, &mut tg).unwrap();
    assert_eq!(s_stats.loss.to_bits(), t_stats.loss.to_bits());
}

#[test]
fn pooling_disabled_baseline_allocates_tensor_payloads_every_round() {
    // the counterpart measurement: with recycling off (the pre-pool
    // engine), per-round heap traffic includes the staging blocks and
    // kernel outputs — orders of magnitude above the pooled byte budget
    let _guard = serial();
    let rt = wide_runtime();
    let st = state(&rt);
    let dag = workload();

    let measure = |pooling: bool| -> (u64, u64, u64) {
        let cfg = EngineConfig { pooling, ..Default::default() };
        let mut session = EngineSession::new(&rt, cfg);
        let mut grads = Grads::default();
        let stats = session.run(&dag, &st, &mut grads).unwrap(); // warmup
        let base = snapshot();
        for _ in 0..3 {
            let mut grads = Grads::default();
            session.run(&dag, &st, &mut grads).unwrap();
        }
        let d = snapshot().delta_since(&base);
        (d.allocs, d.bytes, 3 * stats.executions as u64)
    };

    let (pooled_allocs, pooled_bytes, rounds) = measure(true);
    let (bare_allocs, bare_bytes, _) = measure(false);
    assert!(
        bare_bytes > 4 * pooled_bytes,
        "unpooled rounds must allocate tensor payloads: {bare_bytes} vs {pooled_bytes} \
         pooled bytes over {rounds} rounds"
    );
    assert!(
        bare_allocs > pooled_allocs,
        "unpooled rounds must allocate more often: {bare_allocs} vs {pooled_allocs}"
    );
}

#[test]
fn eval_and_serve_blocks_stay_within_the_rank_alloc_budget() {
    // The eval/serve hot block — forward plane + rank-against-all — must
    // recycle like the training loop does: the query block, every entity
    // chunk and every score output come from the session pool, and the
    // steady-state heap traffic stays under the documented rank budget
    // (eval::rank::{RANK_ALLOC_OVERHEAD, RANK_ALLOC_PER_EXEC}).
    let _guard = serial();
    let rt = wide_runtime();
    let st = state(&rt);
    let (eval_b, eval_chunk) =
        (rt.manifest().dims.eval_b, rt.manifest().dims.eval_chunk);

    // a forward-only eval block: 4 query roots, no Score, no gradients
    let mut dag = QueryDag::default();
    let mut roots = Vec::new();
    for i in 0..4u32 {
        let tree = QueryTree::instantiate(
            Pattern::P2,
            &[i % NE as u32],
            &[i % NR as u32, (i + 1) % NR as u32],
        )
        .unwrap();
        roots.push(dag.add_query_eval(&tree, true).unwrap());
    }

    let mut session = EngineSession::new(&rt, EngineConfig::default());
    let mut ranker = EntityRanker::new();
    let mut scores: Vec<f32> = Vec::new();

    // warmup: pool shelves, slab, ranker scratch, scores capacity
    for _ in 0..2 {
        let (_, reprs) = session.run_forward(&dag, &st, &roots).unwrap();
        ranker.score_all(&rt, &st, &reprs, session.pool(), &mut scores).unwrap();
    }
    let misses_warm = session.pool().stats().misses;

    const RUNS: u64 = 5;
    let blocks = roots.len().div_ceil(eval_b) as u64;
    let chunks = NE.div_ceil(eval_chunk) as u64;
    let execs_per_call = blocks * chunks;
    let mut rounds_per_run = 0u64;
    let base = snapshot();
    for _ in 0..RUNS {
        let (stats, reprs) = session.run_forward(&dag, &st, &roots).unwrap();
        assert_eq!(stats.pool_misses, 0, "steady-state forward blocks must pool");
        rounds_per_run = stats.executions as u64;
        ranker.score_all(&rt, &st, &reprs, session.pool(), &mut scores).unwrap();
    }
    let d = snapshot().delta_since(&base);
    assert_eq!(
        session.pool().stats().misses,
        misses_warm,
        "ranking must be fully served by the warm pool"
    );

    let budget = RUNS
        * (RUN_ALLOC_OVERHEAD
            + rounds_per_run * ROUND_ALLOC_BUDGET
            + RANK_ALLOC_OVERHEAD
            + execs_per_call * RANK_ALLOC_PER_EXEC);
    assert!(
        d.allocs <= budget,
        "eval/serve steady state allocated {} times over {} runs; budget {} \
         ({RANK_ALLOC_OVERHEAD}/call + {RANK_ALLOC_PER_EXEC} x {execs_per_call} launches \
         on top of the engine budget)",
        d.allocs,
        RUNS,
        budget
    );
    // the run_forward reprs (one Vec per root) are the only tensor-sized
    // copies left; everything else is pooled — bytes stay bounded
    let bytes_budget =
        RUNS * (rounds_per_run * ROUND_ALLOC_BYTES_BUDGET + 64 * 1024);
    assert!(
        d.bytes <= bytes_budget,
        "eval/serve steady state allocated {} bytes; budget {}",
        d.bytes,
        bytes_budget
    );
}

#[test]
fn pool_reset_releases_then_rewarms() {
    let _guard = serial();
    let rt = wide_runtime();
    let st = state(&rt);
    let dag = workload();
    let mut session = EngineSession::new(&rt, EngineConfig::default());
    let mut grads = Grads::default();
    session.run(&dag, &st, &mut grads).unwrap();
    session.run(&dag, &st, &mut grads).unwrap();
    assert!(session.pool().stats().pooled_bytes > 0, "warm pool parks buffers");

    // shrink: a memory-pressure hook — drop every parked buffer
    session.pool().reset();
    assert_eq!(session.pool().stats().pooled_bytes, 0);

    // the next run re-allocates (misses), the one after is warm again
    let stats = session.run(&dag, &st, &mut grads).unwrap();
    assert!(stats.pool_misses > 0, "post-reset run must repopulate the pool");
    let stats = session.run(&dag, &st, &mut grads).unwrap();
    assert_eq!(stats.pool_misses, 0, "pool must re-warm after one run");
}

#[test]
fn failed_runs_return_buffers_and_do_not_poison_steady_state() {
    let _guard = serial();
    let rt = wide_runtime();
    let st = state(&rt);
    let dag = workload();
    let mut session = EngineSession::new(&rt, EngineConfig::default());
    let mut grads = Grads::default();
    session.run(&dag, &st, &mut grads).unwrap();
    session.run(&dag, &st, &mut grads).unwrap();
    let parked_before = session.pool().stats().pooled_bytes;

    // intersect4 has no compiled artifact: the run fails mid-DAG, after
    // several successful rounds whose buffers must all come back
    let bad_tree = QueryTree::Intersect(vec![
        QueryTree::Anchor(0),
        QueryTree::Anchor(1),
        QueryTree::Anchor(2),
        QueryTree::Anchor(3),
    ]);
    let mut bad = QueryDag::default();
    let negs: Vec<u32> = (0..N_NEG as u32).collect();
    bad.add_query(&bad_tree, 5, negs, "custom", true).unwrap();
    bad.add_gradient_nodes();
    let mut bad_grads = Grads::default();
    assert!(session.run(&bad, &st, &mut bad_grads).is_err());
    assert!(
        session.pool().stats().pooled_bytes >= parked_before,
        "the failed run must return its buffers (parked {} -> {})",
        parked_before,
        session.pool().stats().pooled_bytes
    );

    // steady state on the good workload survives the failure
    let stats = session.run(&dag, &st, &mut grads).unwrap();
    assert_eq!(stats.pool_misses, 0, "failure must not cost the pool its shelves");

    // repeated failures settle too: identical failing runs stop growing
    // the pool once their (few) shapes are parked
    let mut bad_grads = Grads::default();
    assert!(session.run(&bad, &st, &mut bad_grads).is_err());
    let parked_a = session.pool().stats().pooled_bytes;
    let mut bad_grads = Grads::default();
    assert!(session.run(&bad, &st, &mut bad_grads).is_err());
    assert_eq!(
        session.pool().stats().pooled_bytes,
        parked_a,
        "identical failing runs must not grow the pool"
    );

    // a *mid-gather* failure: the wrong-negative-count bail fires inside
    // the Score coalesce AFTER staging blocks were checked out — the
    // engine's buffer-safe error discipline (`filled` + the coalesce
    // wrapper) must hand them back, so the steady state survives this too
    let tree = QueryTree::instantiate(Pattern::P1, &[0], &[0]).unwrap();
    let mut bad_negs = QueryDag::default();
    bad_negs.add_query(&tree, 1, vec![0, 1], Pattern::P1.name(), true).unwrap();
    bad_negs.add_gradient_nodes();
    let mut g = Grads::default();
    let err = session.run(&bad_negs, &st, &mut g).unwrap_err();
    assert!(format!("{err:#}").contains("negatives"), "{err:#}");
    let stats = session.run(&dag, &st, &mut grads).unwrap();
    assert_eq!(
        stats.pool_misses, 0,
        "a gather-path failure must not cost the pool its shelves"
    );
}
