//! Crash-recovery suite for the checkpoint store: kill the process at
//! every fault-injection site mid-save and prove the previous generation
//! always recovers bitwise; corrupt committed files every way a disk can
//! (bit flip, truncation, torn manifest) and prove the loader refuses
//! with a typed error — zero checksum failures pass silently.
//!
//! The kill tests re-exec this test binary filtered down to
//! `crash_child_runs_to_abort` (a no-op without `NGDB_CRASH_DIR`): the
//! child replays a deterministic mutation schedule, arms
//! `Action::Abort` at the requested site, and dies inside the save. The
//! parent then recovers from the wreckage like a restarted trainer
//! would. Runs in the serial `NGDB_STRESS` CI job too (subprocess spawns
//! + an armed global failpoint registry want --test-threads=1, though
//! `FP_LOCK` keeps the default parallel run correct).

use std::path::PathBuf;
use std::process::Command;
use std::sync::Mutex;
use std::time::Duration;

use ngdb_zoo::model::ModelState;
use ngdb_zoo::runtime::{MockRuntime, Runtime};
use ngdb_zoo::train::checkpoint::{
    AutoCheckpointer, CheckpointPolicy, CheckpointStore, CkptError, SaveKind, FAILPOINT_SITES,
    FP_AFTER_COMMIT, FP_WRITE_TENSOR,
};
use ngdb_zoo::util::failpoint::{self, Action, Trigger};

/// The failpoint registry is process-global: tests that arm sites or run
/// saves while sites may be armed serialize through this.
static FP_LOCK: Mutex<()> = Mutex::new(());

fn lock() -> std::sync::MutexGuard<'static, ()> {
    FP_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

fn tmp(name: &str) -> PathBuf {
    let p = std::env::temp_dir().join(format!("ngdb_crash_{name}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&p);
    p
}

/// The child and the parent must construct the *same* initial state:
/// recovery-after-restart always begins from a fresh init.
fn seeded_state() -> ModelState {
    let rt = MockRuntime::new();
    ModelState::init(rt.manifest(), "mock", 37, 5, None, 7).unwrap()
}

/// Deterministic per-step mutation: a handful of scattered entity rows
/// (data + both moments), one relation row, and the step counter —
/// identical in the child (which saves it) and the parent (which replays
/// it to compute the expected recovery).
fn mutate(state: &mut ModelState, k: u64) {
    let rows = state.entities.rows;
    let dim = state.entities.dim;
    for i in 0..6usize {
        let row = (k as usize * 13 + i * 7) % rows;
        for x in &mut state.entities.data[row * dim..(row + 1) * dim] {
            *x = *x * 0.875 + k as f32 * 0.01 + i as f32 * 0.001;
        }
        state.entities.m[row * dim] = k as f32 * 0.5;
        state.entities.v[row * dim + 1] = k as f32 * 0.25;
        state.dirty.ent.insert(row as u32);
    }
    let rdim = state.relations.dim;
    let rrow = (k % state.relations.rows as u64) as usize;
    for x in &mut state.relations.data[rrow * rdim..(rrow + 1) * rdim] {
        *x += 0.125 * k as f32;
    }
    state.dirty.rel.insert(rrow as u32);
    state.step = k;
}

fn assert_bitwise(expected: &ModelState, restored: &ModelState) {
    assert_eq!(expected.step, restored.step, "recovered step");
    assert_eq!(expected.entities.data, restored.entities.data, "entity data");
    assert_eq!(expected.entities.m, restored.entities.m, "entity m");
    assert_eq!(expected.entities.v, restored.entities.v, "entity v");
    assert_eq!(expected.relations.data, restored.relations.data, "relation data");
    assert_eq!(expected.relations.m, restored.relations.m, "relation m");
    assert_eq!(expected.relations.v, restored.relations.v, "relation v");
}

/// Run `k` mutation+save rounds against a fresh store in `dir`; round 1
/// commits a full base, later rounds commit deltas.
fn save_rounds(dir: &PathBuf, state: &mut ModelState, rounds: u64) -> CheckpointStore {
    let mut store = CheckpointStore::open(dir);
    for k in 1..=rounds {
        mutate(state, k);
        store.absorb_dirty(&state.dirty);
        state.dirty.reset_to(k);
        store.save(state).unwrap();
    }
    store
}

// ---------------------------------------------------------------------------
// subprocess kill sweep
// ---------------------------------------------------------------------------

/// Child half of the kill sweep — a no-op unless spawned by the sweep
/// with `NGDB_CRASH_DIR` set. Saves `NGDB_CRASH_AT - 1` generations
/// normally, arms `NGDB_CRASH_SITE` with an abort, and dies inside the
/// final save.
#[test]
fn crash_child_runs_to_abort() {
    let Ok(dir) = std::env::var("NGDB_CRASH_DIR") else { return };
    let site = std::env::var("NGDB_CRASH_SITE").expect("NGDB_CRASH_SITE");
    let crash_at: u64 = std::env::var("NGDB_CRASH_AT").expect("NGDB_CRASH_AT").parse().unwrap();
    let mut state = seeded_state();
    let mut store = CheckpointStore::open(&dir);
    for k in 1..=crash_at {
        mutate(&mut state, k);
        store.absorb_dirty(&state.dirty);
        state.dirty.reset_to(k);
        if k == crash_at {
            failpoint::set(&site, Action::Abort, Trigger::Once(1));
        }
        store.save(&state).unwrap();
        println!("SAVE_OK {k}");
    }
    // reachable only if the armed site was never hit during the save —
    // that's a hole in the fault-injection coverage, not a pass
    panic!("failpoint site {site:?} never fired during save {crash_at}");
}

#[test]
fn kill_during_save_at_every_site_recovers_the_latest_committed_generation() {
    let _g = lock(); // the post-recovery save below must not see armed sites
    let exe = std::env::current_exe().unwrap();
    for crash_at in [2u64, 3] {
        for site in FAILPOINT_SITES {
            let dir = tmp(&format!("kill_{crash_at}_{}", site.replace('.', "_")));
            let out = Command::new(&exe)
                .arg("crash_child_runs_to_abort")
                .arg("--exact")
                .arg("--nocapture")
                .arg("--test-threads=1")
                .env("NGDB_CRASH_DIR", &dir)
                .env("NGDB_CRASH_SITE", site)
                .env("NGDB_CRASH_AT", crash_at.to_string())
                .output()
                .expect("spawning crash child");
            assert!(
                !out.status.success(),
                "child must die mid-save at {site} (crash_at={crash_at}): {}",
                String::from_utf8_lossy(&out.stdout)
            );

            // everything before the aborted save committed; the abort
            // site decides whether the final save made it — only
            // after-commit lands past the rename
            let committed = if site == FP_AFTER_COMMIT { crash_at } else { crash_at - 1 };
            let mut expected = seeded_state();
            for k in 1..=committed {
                mutate(&mut expected, k);
                expected.dirty.reset_to(k);
            }

            let mut restored = seeded_state();
            let store = CheckpointStore::open(&dir); // sweeps stale staging
            let gen = store
                .load_latest(&mut restored)
                .unwrap_or_else(|e| panic!("recovery after kill at {site}: {e}"));
            assert_eq!(gen, committed, "recovered generation after kill at {site}");
            assert_bitwise(&expected, &restored);
            // and the survivor is a valid base for further saves
            let mut store = store;
            restored.dirty.ent.insert(0);
            restored.step += 1;
            store.absorb_dirty(&restored.dirty);
            store.save(&restored).unwrap();
            std::fs::remove_dir_all(&dir).ok();
        }
    }
}

// ---------------------------------------------------------------------------
// corruption detection (typed errors, no silent garbage)
// ---------------------------------------------------------------------------

#[test]
fn bit_flipped_tensor_file_is_a_typed_checksum_error() {
    let _g = lock();
    let dir = tmp("bitflip");
    let mut state = seeded_state();
    save_rounds(&dir, &mut state, 1);
    let path = dir.join("gen-000001").join("ent.data.bin");
    let mut bytes = std::fs::read(&path).unwrap();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0x10; // one flipped bit, same length
    std::fs::write(&path, &bytes).unwrap();

    let mut restored = seeded_state();
    let err = CheckpointStore::open(&dir).load_latest(&mut restored).unwrap_err();
    assert!(
        matches!(err, CkptError::ChecksumMismatch { .. }),
        "bit flip must surface as ChecksumMismatch, got: {err}"
    );
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn truncated_tensor_file_is_a_typed_length_error() {
    let _g = lock();
    let dir = tmp("trunc");
    let mut state = seeded_state();
    save_rounds(&dir, &mut state, 1);
    let path = dir.join("gen-000001").join("rel.m.bin");
    let bytes = std::fs::read(&path).unwrap();
    std::fs::write(&path, &bytes[..bytes.len() - 4]).unwrap();

    let mut restored = seeded_state();
    let err = CheckpointStore::open(&dir).load_latest(&mut restored).unwrap_err();
    assert!(
        matches!(err, CkptError::LengthMismatch { .. }),
        "truncation must surface as LengthMismatch, got: {err}"
    );
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn corrupt_newest_manifest_falls_back_to_the_previous_generation() {
    let _g = lock();
    let dir = tmp("mf_fallback");
    let mut state = seeded_state();
    save_rounds(&dir, &mut state, 2);
    // damage generation 2's commit record; generation 1 must win
    let path = dir.join("gen-000002").join("MANIFEST");
    let mut bytes = std::fs::read(&path).unwrap();
    bytes[20] ^= 0x01;
    std::fs::write(&path, &bytes).unwrap();

    let mut expected = seeded_state();
    mutate(&mut expected, 1);
    let mut restored = seeded_state();
    let gen = CheckpointStore::open(&dir).load_latest(&mut restored).unwrap();
    assert_eq!(gen, 1, "the damaged generation must be skipped");
    assert_bitwise(&expected, &restored);
    std::fs::remove_dir_all(&dir).ok();
}

// ---------------------------------------------------------------------------
// hostile roots (typed Io errors, never a panic)
// ---------------------------------------------------------------------------

#[test]
fn root_occupied_by_a_regular_file_is_a_typed_io_error() {
    let _g = lock();
    let dir = tmp("rootfile");
    let _ = std::fs::remove_file(&dir); // tmp() only sweeps directories
    std::fs::write(&dir, b"not a directory").unwrap();
    let mut state = seeded_state();
    mutate(&mut state, 1);
    let mut store = CheckpointStore::open(&dir); // opening must not panic
    store.absorb_dirty(&state.dirty);
    match store.save(&state).unwrap_err() {
        CkptError::Io { op, path, .. } => {
            assert_eq!(op, "creating checkpoint root");
            assert_eq!(path, dir);
        }
        other => panic!("a file in the root's place must fail as Io, got {other}"),
    }
    // loads see "no checkpoint yet" — the documented semantics for an
    // unreadable root — through both the heap and the mapped path
    assert!(matches!(
        CheckpointStore::open(&dir).load_latest(&mut seeded_state()),
        Err(CkptError::NoCheckpoint { .. })
    ));
    assert!(matches!(
        CheckpointStore::open(&dir).load_snapshot_mapped(&seeded_state(), None),
        Err(CkptError::NoCheckpoint { .. })
    ));
    std::fs::remove_file(&dir).ok();
}

#[cfg(unix)]
#[test]
fn permission_denied_generation_is_a_typed_io_error_not_a_panic() {
    use std::os::unix::fs::PermissionsExt;
    let _g = lock();
    let dir = tmp("permdenied");
    let mut state = seeded_state();
    save_rounds(&dir, &mut state, 1);
    let gen_dir = dir.join("gen-000001");
    let open_perms = std::fs::metadata(&gen_dir).unwrap().permissions();
    std::fs::set_permissions(&gen_dir, std::fs::Permissions::from_mode(0o000)).unwrap();
    // probe first: privileged users (root CI containers) bypass mode
    // bits, so the denial cannot be simulated there and the leg is
    // vacuous — but must still not panic
    let denied = std::fs::read(gen_dir.join("MANIFEST")).is_err();
    let heap = CheckpointStore::open(&dir).load_latest(&mut seeded_state());
    let mapped = CheckpointStore::open(&dir).load_snapshot_mapped(&seeded_state(), None);
    std::fs::set_permissions(&gen_dir, open_perms).unwrap();
    if denied {
        match heap.unwrap_err() {
            CkptError::Io { op, path, .. } => {
                assert_eq!(op, "reading");
                assert!(path.starts_with(&gen_dir), "{}", path.display());
            }
            other => panic!("a permission-denied generation must fail as Io, got {other}"),
        }
        let err = mapped.unwrap_err();
        assert!(matches!(err, CkptError::Io { .. }), "mapped load must type it too: {err}");
    } else {
        heap.expect("with mode bits bypassed the load must simply succeed");
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[cfg(unix)]
#[test]
fn read_only_root_fails_saves_with_a_typed_io_error() {
    use std::os::unix::fs::PermissionsExt;
    let _g = lock();
    let dir = tmp("roroot");
    let mut state = seeded_state();
    let mut store = save_rounds(&dir, &mut state, 1); // gen 1 commits writable
    let open_perms = std::fs::metadata(&dir).unwrap().permissions();
    std::fs::set_permissions(&dir, std::fs::Permissions::from_mode(0o555)).unwrap();
    let denied = std::fs::write(dir.join(".probe"), b"x").is_err();
    mutate(&mut state, 2);
    store.absorb_dirty(&state.dirty);
    state.dirty.reset_to(2);
    let result = store.save(&state);
    std::fs::set_permissions(&dir, open_perms).unwrap();
    std::fs::remove_file(dir.join(".probe")).ok();
    if denied {
        let err = result.unwrap_err();
        assert!(matches!(err, CkptError::Io { .. }), "read-only root must be Io: {err}");
        // the generation committed before the root went read-only still
        // recovers — a failed save never poisons existing data
        let mut restored = seeded_state();
        assert_eq!(CheckpointStore::open(&dir).load_latest(&mut restored).unwrap(), 1);
    } else {
        result.expect("with mode bits bypassed the save must simply succeed");
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn injected_short_write_never_commits_and_the_retry_succeeds() {
    let _g = lock();
    let dir = tmp("shortwrite");
    let mut state = seeded_state();
    mutate(&mut state, 1);
    let mut store = CheckpointStore::open(&dir);
    store.absorb_dirty(&state.dirty);
    failpoint::set(FP_WRITE_TENSOR, Action::ShortWrite, Trigger::Once(1));
    let err = store.save(&state).unwrap_err();
    assert!(matches!(err, CkptError::Io { .. }), "{err}");
    assert!(
        matches!(
            CheckpointStore::open(&dir).load_latest(&mut seeded_state()),
            Err(CkptError::NoCheckpoint { .. })
        ),
        "a torn staging write must leave nothing committed"
    );
    // pending dirt survived the failure; the clean retry commits gen 1
    store.save(&state).unwrap();
    let mut restored = seeded_state();
    assert_eq!(CheckpointStore::open(&dir).load_latest(&mut restored).unwrap(), 1);
    assert_bitwise(&state, &restored);
    std::fs::remove_dir_all(&dir).ok();
}

// ---------------------------------------------------------------------------
// incremental replay parity
// ---------------------------------------------------------------------------

#[test]
fn base_plus_delta_replay_is_bitwise_identical_to_a_full_save() {
    let _g = lock();
    let dir_inc = tmp("replay_inc");
    let dir_full = tmp("replay_full");
    let mut state = seeded_state();
    let store = save_rounds(&dir_inc, &mut state, 4); // 1 full + 3 deltas
    assert_eq!(store.generations(), vec![1, 2, 3, 4]);
    // the same final state, saved cold as one full generation
    let mut full_store = CheckpointStore::open(&dir_full);
    let report = full_store.save(&state).unwrap();
    assert_eq!(report.kind, SaveKind::Full);

    let mut via_deltas = seeded_state();
    let mut via_full = seeded_state();
    CheckpointStore::open(&dir_inc).load_latest(&mut via_deltas).unwrap();
    CheckpointStore::open(&dir_full).load_latest(&mut via_full).unwrap();
    assert_bitwise(&via_full, &via_deltas);
    assert_bitwise(&state, &via_deltas);
    std::fs::remove_dir_all(&dir_inc).ok();
    std::fs::remove_dir_all(&dir_full).ok();
}

// ---------------------------------------------------------------------------
// trainer-side robustness: retry/backoff + graceful degradation
// ---------------------------------------------------------------------------

fn quick_policy() -> CheckpointPolicy {
    CheckpointPolicy {
        every_steps: 1,
        max_retries: 3,
        retry_backoff: Duration::from_millis(1),
    }
}

#[test]
fn transient_io_error_is_retried_and_counted() {
    let _g = lock();
    let dir = tmp("retry");
    let mut state = seeded_state();
    mutate(&mut state, 1);
    let mut ac = AutoCheckpointer::new(CheckpointStore::open(&dir), quick_policy());
    failpoint::set(FP_WRITE_TENSOR, Action::Error, Trigger::Once(1));
    let out = ac.after_step(&state).expect("cadence of 1 must save every step");
    assert!(out.ok(), "one transient error must not fail the save: {:?}", out.error);
    assert_eq!(out.retries, 1);
    let m = ac.metrics();
    assert_eq!(m.saves_full.get(), 1);
    assert_eq!(m.retries_full.get(), 1);
    assert_eq!(m.failures_full.get(), 0);
    assert_eq!(m.save_bytes.count(), 1);
    assert_eq!(m.save_seconds.count(), 1);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn permanent_io_failure_degrades_gracefully_and_later_saves_catch_up() {
    let _g = lock();
    let dir = tmp("permafail");
    let mut state = seeded_state();
    mutate(&mut state, 1);
    let mut ac = AutoCheckpointer::new(CheckpointStore::open(&dir), quick_policy());
    assert!(ac.after_step(&state).unwrap().ok(), "baseline full save");

    mutate(&mut state, 2);
    failpoint::set(FP_WRITE_TENSOR, Action::Error, Trigger::Always);
    let out = ac.after_step(&state).expect("cadence of 1 must attempt every step");
    failpoint::clear(FP_WRITE_TENSOR);
    assert!(!out.ok(), "exhausted retries must report failure, not panic");
    assert_eq!(out.retries, 3, "max_retries attempts before giving up");
    assert!(out.error.as_deref().unwrap_or("").contains("injected"), "{:?}", out.error);
    let m = ac.metrics();
    assert_eq!(m.failures_delta.get(), 1, "the failed save was delta-eligible");
    assert_eq!(m.retries_delta.get(), 3);

    // the dirty rows from the failed save were retained: the next save
    // carries steps 2 AND 3, and a cold load sees everything
    mutate(&mut state, 3);
    let out = ac.after_step(&state).expect("cadence");
    assert!(out.ok(), "recovery save after the outage: {:?}", out.error);
    let mut expected = seeded_state();
    for k in 1..=3 {
        mutate(&mut expected, k);
        expected.dirty.reset_to(k);
    }
    let mut restored = seeded_state();
    CheckpointStore::open(&dir).load_latest(&mut restored).unwrap();
    assert_bitwise(&expected, &restored);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn failure_after_commit_is_retried_as_a_sibling_generation() {
    let _g = lock();
    let dir = tmp("after_commit");
    let mut state = seeded_state();
    mutate(&mut state, 1);
    let mut ac = AutoCheckpointer::new(CheckpointStore::open(&dir), quick_policy());
    assert!(ac.after_step(&state).unwrap().ok());

    // the generation lands on disk but the save *reports* failure (e.g.
    // the root-dir fsync raced a remount): the retry must commit a
    // sibling delta against the same parent, and recovery takes the
    // newest — never a half-acknowledged orphan ahead of it
    mutate(&mut state, 2);
    failpoint::set(FP_AFTER_COMMIT, Action::Error, Trigger::Once(1));
    let out = ac.after_step(&state).expect("cadence");
    assert!(out.ok(), "{:?}", out.error);
    assert_eq!(out.retries, 1);
    assert_eq!(
        ac.store().generations(),
        vec![1, 2, 3],
        "the orphaned gen 2 stays on disk; the retry committed gen 3"
    );
    let mut restored = seeded_state();
    let gen = CheckpointStore::open(&dir).load_latest(&mut restored).unwrap();
    assert_eq!(gen, 3);
    assert_bitwise(&state, &restored);
    std::fs::remove_dir_all(&dir).ok();
}
