//! Train/serve parity: the forward plane must produce **bitwise** the same
//! reprs as the training path — for the same queries fused into a training
//! DAG (Score + VJP nodes present) and for the very same forward-only DAG
//! driven through both entry points — and forward runs over a
//! moment-free [`ModelSnapshot`] must match runs over the live state.

use ngdb_zoo::exec::{EngineConfig, EngineSession, ForwardSession, Grads};
use ngdb_zoo::model::{ModelSnapshot, ModelState};
use ngdb_zoo::query::{Pattern, QueryDag, QueryTree};
use ngdb_zoo::runtime::{MockRuntime, Runtime};

const NE: usize = 12;
const NR: usize = 6;

fn state(rt: &MockRuntime) -> ModelState {
    ModelState::init(rt.manifest(), "mock", NE, NR, None, 3).unwrap()
}

/// A deterministic mixed workload covering every operator family.
fn trees() -> Vec<QueryTree> {
    let mut out = Vec::new();
    for i in 0..6u32 {
        out.push(
            QueryTree::instantiate(Pattern::P1, &[i % NE as u32], &[i % NR as u32]).unwrap(),
        );
    }
    for i in 0..4u32 {
        out.push(
            QueryTree::instantiate(
                Pattern::P2,
                &[(i + 2) % NE as u32],
                &[i % NR as u32, (i + 1) % NR as u32],
            )
            .unwrap(),
        );
    }
    for i in 0..4u32 {
        out.push(
            QueryTree::instantiate(
                Pattern::I2,
                &[i % NE as u32, (i + 5) % NE as u32],
                &[i % NR as u32, (i + 2) % NR as u32],
            )
            .unwrap(),
        );
    }
    for i in 0..2u32 {
        out.push(
            QueryTree::instantiate(
                Pattern::Up,
                &[i % NE as u32, (i + 3) % NE as u32],
                &[i % NR as u32, (i + 1) % NR as u32, (i + 2) % NR as u32],
            )
            .unwrap(),
        );
        out.push(
            QueryTree::instantiate(
                Pattern::In2,
                &[i % NE as u32, (i + 1) % NE as u32],
                &[i % NR as u32, (i + 3) % NR as u32],
            )
            .unwrap(),
        );
    }
    out
}

fn assert_bitwise(a: &[Vec<f32>], b: &[Vec<f32>], tag: &str) {
    assert_eq!(a.len(), b.len(), "{tag}: root count");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.len(), y.len(), "{tag}: root {i} width");
        for (p, q) in x.iter().zip(y) {
            assert_eq!(p.to_bits(), q.to_bits(), "{tag}: root {i} diverged");
        }
    }
}

#[test]
fn forward_plane_matches_the_training_path_bitwise() {
    // Same queries, two lowerings: the training DAG carries Score heads +
    // gradient nodes and runs with Grads; the forward DAG carries neither
    // and runs with none. Operators are row-local, so the root reprs must
    // agree bit for bit even though the fused schedules differ.
    let rt = MockRuntime::new();
    let st = state(&rt);
    let trees = trees();

    let mut train_dag = QueryDag::default();
    let mut train_roots = Vec::new();
    for t in &trees {
        train_roots.push(train_dag.add_query(t, 5, vec![0, 1], "mixed", true).unwrap());
    }
    train_dag.add_gradient_nodes();
    let mut session = EngineSession::new(&rt, EngineConfig::default());
    let mut grads = Grads::default();
    let (_, train_reprs) =
        session.run_with_outputs(&train_dag, &st, &mut grads, &train_roots).unwrap();
    assert!(!grads.ent.is_empty(), "the training leg really trained");

    let mut fwd_dag = QueryDag::default();
    let mut fwd_roots = Vec::new();
    for t in &trees {
        fwd_roots.push(fwd_dag.add_query_eval(t, true).unwrap());
    }
    let (stats, fwd_reprs) = session.run_forward(&fwd_dag, &st, &fwd_roots).unwrap();
    assert_eq!(stats.operators, fwd_dag.len(), "forward plane ran every node");
    assert_eq!(stats.loss, 0.0, "no Score node, no loss");

    assert_bitwise(&train_reprs, &fwd_reprs, "train-vs-forward");
}

#[test]
fn both_entry_points_agree_on_the_same_forward_dag() {
    // The crisp acceptance check: ONE fused forward-only DAG, executed
    // through the training entry point (dummy Grads) and the forward
    // plane — identical schedule, identical reprs.
    let rt = MockRuntime::new();
    let st = state(&rt);
    let mut dag = QueryDag::default();
    let mut roots = Vec::new();
    for t in &trees() {
        roots.push(dag.add_query_eval(t, true).unwrap());
    }

    let mut s_train = EngineSession::new(&rt, EngineConfig::default());
    let mut grads = Grads::default();
    let (st_train, reprs_train) =
        s_train.run_with_outputs(&dag, &st, &mut grads, &roots).unwrap();
    assert_eq!(grads.ent.len(), 0, "a forward-only DAG accumulates nothing");

    let mut s_fwd = EngineSession::new(&rt, EngineConfig::default());
    let (st_fwd, reprs_fwd) = s_fwd.run_forward(&dag, &st, &roots).unwrap();

    assert_eq!(st_train.schedule, st_fwd.schedule, "same Max-Fillness schedule");
    assert_eq!(st_train.fillness, st_fwd.fillness);
    assert_bitwise(&reprs_train, &reprs_fwd, "same-dag");
}

#[test]
fn forward_plane_rejects_gradient_dags() {
    let rt = MockRuntime::new();
    let st = state(&rt);
    let tree = QueryTree::instantiate(Pattern::P1, &[0], &[0]).unwrap();

    // Score head without gradient nodes
    let mut scored = QueryDag::default();
    scored.add_query(&tree, 1, vec![0, 1], "1p", true).unwrap();
    let mut session = EngineSession::new(&rt, EngineConfig::default());
    let err = session.run_forward(&scored, &st, &[]).unwrap_err();
    assert!(format!("{err:#}").contains("forward"), "{err:#}");

    // full training DAG
    let mut train = QueryDag::default();
    train.add_query(&tree, 1, vec![0, 1], "1p", true).unwrap();
    train.add_gradient_nodes();
    let err = session.run_forward(&train, &st, &[]).unwrap_err();
    assert!(format!("{err:#}").contains("forward"), "{err:#}");

    // the session survives the rejections and still trains
    let mut grads = Grads::default();
    assert!(session.run(&train, &st, &mut grads).is_ok());
}

#[test]
fn snapshots_serve_bitwise_identically_and_stay_isolated() {
    let rt = MockRuntime::new();
    let mut st = state(&rt);
    let snap = ModelSnapshot::capture(&st);
    let mut dag = QueryDag::default();
    let mut roots = Vec::new();
    for t in &trees() {
        roots.push(dag.add_query_eval(t, true).unwrap());
    }

    let mut live_session = EngineSession::new(&rt, EngineConfig::default());
    let (_, live_reprs) = live_session.run_forward(&dag, &st, &roots).unwrap();

    let mut fwd = ForwardSession::new(&rt, EngineConfig::default());
    let (_, snap_reprs) = fwd.run(&dag, &snap, &roots).unwrap();
    assert_bitwise(&live_reprs, &snap_reprs, "live-vs-snapshot");

    // mutate the live state (a trainer stepping): the snapshot must not move
    st.entities.data.iter_mut().for_each(|x| *x += 1.0);
    let (_, snap_again) = fwd.run(&dag, &snap, &roots).unwrap();
    assert_bitwise(&snap_reprs, &snap_again, "snapshot-after-train");
    let (_, live_after) = live_session.run_forward(&dag, &st, &roots).unwrap();
    assert_ne!(
        live_after[0][0].to_bits(),
        snap_reprs[0][0].to_bits(),
        "the live state really moved — isolation was actually exercised"
    );
}

#[test]
fn forward_sessions_reuse_one_worker_across_runs() {
    let rt = MockRuntime::new();
    let st = state(&rt);
    let snap = ModelSnapshot::capture(&st);
    let mut fwd = ForwardSession::new(&rt, EngineConfig::default());
    assert_eq!(fwd.worker_spawns(), 1);
    for salt in 0..4u32 {
        let mut dag = QueryDag::default();
        let tree =
            QueryTree::instantiate(Pattern::P1, &[salt % NE as u32], &[salt % NR as u32])
                .unwrap();
        let root = dag.add_query_eval(&tree, true).unwrap();
        let (stats, reprs) = fwd.run(&dag, &snap, &[root]).unwrap();
        assert_eq!(reprs.len(), 1);
        assert!(stats.executions > 0);
    }
    assert_eq!(fwd.worker_spawns(), 1, "forward runs must not spawn workers");
}
