//! Host-kernel equivalence property suite: the vectorized multi-threaded
//! compute path must be **bitwise indistinguishable** from the serial
//! vectorized path on both planes — same loss bits, same gradient bits,
//! same forward reprs — across random query DAGs × thread counts {1,2,4}.
//! The deterministic-reduction mode makes this a hard guarantee, not a
//! tolerance: chunk boundaries are a pure function of the row count and
//! per-chunk partials fold in chunk order, so the thread count can never
//! change a single bit.
//!
//! The pre-vectorization scalar loops (`KernelPath::Reference`) are held to
//! a *tolerance* instead — lane-chunked accumulation legitimately reorders
//! floating-point sums.

use ngdb_zoo::exec::{EngineConfig, EngineSession, Grads, StepStats};
use ngdb_zoo::model::ModelState;
use ngdb_zoo::query::Pattern;
use ngdb_zoo::runtime::{HostKernelConfig, MockRuntime, Runtime};
use ngdb_zoo::util::proptest::prop_check_shrink;
use ngdb_zoo::util::proptest::queries::{self, QuerySet};
use ngdb_zoo::util::rng::Rng;

const NE: usize = 12; // mock entity rows
const NR: usize = 6; // mock relation rows
const NEG: usize = 2; // mock n_neg
const D: usize = 32; // wide enough that 8-lane chunking engages

/// A mock runtime whose host kernels run on `threads` lanes, with the
/// size threshold disabled so even unit-test-sized batches take the
/// threaded path.
fn threaded_runtime(threads: usize) -> MockRuntime {
    let cfg = HostKernelConfig { threads, par_min_elems: 0, ..Default::default() };
    MockRuntime::with_config(D, NEG, &[4, 16, 64]).with_kernel_config(cfg)
}

fn state(rt: &MockRuntime) -> ModelState {
    ModelState::init(rt.manifest(), "mock", NE, NR, None, 3).unwrap()
}

/// One training run through a fresh warm session: stats + gradients.
fn run_train(rt: &MockRuntime, set: &QuerySet) -> Result<(StepStats, Grads), String> {
    let st = state(rt);
    let dag = set.train_dag();
    let mut session = EngineSession::new(rt, EngineConfig::default());
    let mut grads = Grads::default();
    let stats = session.run(&dag, &st, &mut grads).map_err(|e| format!("{e:#}"))?;
    Ok((stats, grads))
}

/// Bit-exact comparison of two training runs: schedule, loss bits, every
/// gradient entry (`f32::to_bits`). Returns the first divergence.
fn assert_bitwise_equal(
    (s_a, g_a): &(StepStats, Grads),
    (s_b, g_b): &(StepStats, Grads),
) -> Result<(), String> {
    if s_a.executions != s_b.executions {
        return Err(format!("round counts: {} vs {}", s_a.executions, s_b.executions));
    }
    if s_a.schedule != s_b.schedule {
        return Err("schedules diverge".into());
    }
    if s_a.loss.to_bits() != s_b.loss.to_bits() {
        return Err(format!("loss not bit-identical: {} vs {}", s_a.loss, s_b.loss));
    }
    for (map_a, map_b, tag) in
        [(&g_a.ent, &g_b.ent, "ent"), (&g_a.rel, &g_b.rel, "rel")]
    {
        if map_a.len() != map_b.len() {
            return Err(format!("{tag} key counts: {} vs {}", map_a.len(), map_b.len()));
        }
        for (k, v) in map_a {
            let w = map_b.get(k).ok_or_else(|| format!("{tag} missing key {k}"))?;
            for (i, (x, y)) in v.iter().zip(w).enumerate() {
                if x.to_bits() != y.to_bits() {
                    return Err(format!("{tag}[{k}][{i}]: {x} vs {y} (bits differ)"));
                }
            }
        }
    }
    if g_a.dense.len() != g_b.dense.len() {
        return Err("dense key counts differ".into());
    }
    for (k, v) in &g_a.dense {
        let w = g_b.dense.get(k).ok_or_else(|| format!("dense missing key {k}"))?;
        for (i, (x, y)) in v.iter().zip(w).enumerate() {
            if x.to_bits() != y.to_bits() {
                return Err(format!("dense[{k}][{i}]: {x} vs {y} (bits differ)"));
            }
        }
    }
    Ok(())
}

#[test]
fn training_grads_are_bitwise_identical_across_thread_counts() {
    let kg = queries::toy_kg();
    prop_check_shrink(
        "host-kernel thread-count invariance (train plane)",
        12,
        |rng| queries::random_set(rng, &kg, &Pattern::ALL, 12, NE as u32, NR as u32, NEG),
        QuerySet::shrink,
        |set| {
            if set.is_empty() {
                return Ok(());
            }
            let serial = run_train(&threaded_runtime(1), set)?;
            for threads in [2usize, 4] {
                let multi = run_train(&threaded_runtime(threads), set)?;
                assert_bitwise_equal(&serial, &multi)
                    .map_err(|e| format!("threads={threads}: {e}"))?;
            }
            Ok(())
        },
    );
}

/// Forward-plane check body: run the eval DAG at 1/2/4 threads and diff
/// every repr bit for bit.
fn check_forward(set: &QuerySet) -> Result<(), String> {
    if set.is_empty() {
        return Ok(());
    }
    let run = |threads: usize| -> Result<Vec<Vec<f32>>, String> {
        let rt = threaded_runtime(threads);
        let st = state(&rt);
        let (dag, roots) = set.forward_dag(true);
        let mut session = EngineSession::new(&rt, EngineConfig::default());
        let (_, reprs) =
            session.run_forward(&dag, &st, &roots).map_err(|e| format!("{e:#}"))?;
        Ok(reprs)
    };
    let serial = run(1)?;
    for threads in [2usize, 4] {
        let multi = run(threads)?;
        if serial.len() != multi.len() {
            return Err(format!("repr counts: {} vs {}", serial.len(), multi.len()));
        }
        for (qi, (a, b)) in serial.iter().zip(&multi).enumerate() {
            for (i, (x, y)) in a.iter().zip(b).enumerate() {
                if x.to_bits() != y.to_bits() {
                    return Err(format!(
                        "threads={threads}: repr[{qi}][{i}]: {x} vs {y} (bits differ)"
                    ));
                }
            }
        }
    }
    Ok(())
}

#[test]
fn forward_plane_reprs_are_bitwise_identical_across_thread_counts() {
    let kg = queries::toy_kg();
    prop_check_shrink(
        "host-kernel thread-count invariance (forward plane)",
        10,
        |rng| queries::random_set(rng, &kg, &Pattern::ALL, 10, NE as u32, NR as u32, NEG),
        QuerySet::shrink,
        check_forward,
    );
}

#[test]
fn rank_against_all_is_bitwise_identical_across_thread_counts() {
    use ngdb_zoo::eval::rank::EntityRanker;
    let kg = queries::toy_kg();
    let mut rng = Rng::new(17);
    let set = queries::random_set(&mut rng, &kg, &Pattern::ALL, 8, NE as u32, NR as u32, NEG);
    if set.is_empty() {
        return;
    }
    let run = |threads: usize| -> Vec<u32> {
        let rt = threaded_runtime(threads).with_eval_dims(4, 8);
        let st = state(&rt);
        let (dag, roots) = set.forward_dag(true);
        let mut session = EngineSession::new(&rt, EngineConfig::default());
        let (_, reprs) = session.run_forward(&dag, &st, &roots).unwrap();
        let mut ranker = EntityRanker::new();
        let mut scores: Vec<f32> = Vec::new();
        ranker.score_all(&rt, &st, &reprs, session.pool(), &mut scores).unwrap();
        scores.iter().map(|s| s.to_bits()).collect()
    };
    let serial = run(1);
    for threads in [2usize, 4] {
        assert_eq!(run(threads), serial, "rank scores must not depend on thread count");
    }
}

#[test]
fn reference_scalar_path_agrees_with_vectorized_within_tolerance() {
    // the roofline baseline: old seed loops vs the lane-chunked kernels.
    // Different summation order — tolerance, not bits.
    let close = |a: f32, b: f32, tol: f32| (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs()));
    let kg = queries::toy_kg();
    let mut rng = Rng::new(11);
    let mut checked = 0usize;
    while checked < 5 {
        let set = queries::random_set(&mut rng, &kg, &Pattern::ALL, 10, NE as u32, NR as u32, NEG);
        if set.is_empty() {
            continue;
        }
        checked += 1;
        let vectorized = run_train(&threaded_runtime(4), &set).unwrap();
        let reference_rt =
            MockRuntime::with_config(D, NEG, &[4, 16, 64]).with_reference_kernels();
        let reference = run_train(&reference_rt, &set).unwrap();
        assert_eq!(vectorized.0.executions, reference.0.executions);
        let (lv, lr) = (vectorized.0.loss, reference.0.loss);
        assert!(
            (lv - lr).abs() <= 1e-4 * (1.0 + lr.abs()),
            "loss drifted past tolerance: {lv} vs {lr}"
        );
        for (map_v, map_r, tag) in [
            (&vectorized.1.ent, &reference.1.ent, "ent"),
            (&vectorized.1.rel, &reference.1.rel, "rel"),
        ] {
            assert_eq!(map_v.len(), map_r.len(), "{tag} key counts");
            for (k, v) in map_v {
                let w = &map_r[k];
                for (i, (x, y)) in v.iter().zip(w).enumerate() {
                    assert!(close(*x, *y, 1e-3), "{tag}[{k}][{i}]: {x} vs {y}");
                }
            }
        }
    }
}
