//! End-to-end integration over the real AOT artifacts (requires
//! `make artifacts`). These tests exercise the full three-layer stack:
//! Rust coordinator → PJRT CPU client → XLA executables lowered from the
//! JAX/Pallas compute path.
//!
//! Gated behind the `pjrt` feature: the default hermetic build has no
//! artifact runtime, and CI has no XLA libraries (see ROADMAP open items).

#![cfg(feature = "pjrt")]

use std::sync::Arc;

use ngdb_zoo::config::{Batching, ExperimentConfig, Pipelining, Semantic};
use ngdb_zoo::eval::rank;
use ngdb_zoo::exec::{Engine, EngineConfig, Grads};
use ngdb_zoo::kg::{descriptions::Descriptions, KgSpec, KgStore};
use ngdb_zoo::model::ModelState;
use ngdb_zoo::query::{Pattern, QueryDag, QueryTree};
use ngdb_zoo::runtime::{PjrtRuntime, Runtime};
use ngdb_zoo::semantic::{DecoupledCache, JointEncoder, SemanticSource};
use ngdb_zoo::train::Trainer;

fn artifacts_dir() -> String {
    format!("{}/artifacts", env!("CARGO_MANIFEST_DIR"))
}

fn runtime() -> PjrtRuntime {
    PjrtRuntime::open(&artifacts_dir()).expect("run `make artifacts` before cargo test")
}

fn toy_kg() -> Arc<KgStore> {
    Arc::new(KgSpec::preset("toy", 1.0).unwrap().generate().unwrap())
}

fn state_for(rt: &PjrtRuntime, model: &str, kg: &KgStore) -> ModelState {
    ModelState::init(rt.manifest(), model, kg.n_entities, kg.n_relations,
        Some(&artifacts_dir()), 11).unwrap()
}

fn cfg(model: &str, steps: usize) -> ExperimentConfig {
    ExperimentConfig {
        model: model.into(),
        steps,
        batch_queries: 64,
        batching: Batching::OperatorLevel,
        pipelining: Pipelining::Sync,
        patterns: vec![Pattern::P1, Pattern::P2, Pattern::I2, Pattern::U2],
        lr: 1e-2, // aggressive lr so few steps show a trend on the toy graph
        seed: 7,
        artifacts_dir: artifacts_dir(),
        ..Default::default()
    }
}

#[test]
fn gqe_end_to_end_loss_decreases() {
    let rt = runtime();
    let kg = toy_kg();
    let mut state = state_for(&rt, "gqe", &kg);
    let report = Trainer::new(&rt, Arc::clone(&kg), cfg("gqe", 12))
        .train(&mut state)
        .unwrap();
    let first = report.loss_curve[0];
    let last = *report.loss_curve.last().unwrap();
    assert!(
        last < first,
        "loss should decrease: first={first:.4} last={last:.4} curve={:?}",
        report.loss_curve
    );
    assert!(report.loss_curve.iter().all(|l| l.is_finite()));
}

#[test]
fn all_five_models_train_one_step() {
    let rt = runtime();
    let kg = toy_kg();
    for model in ["gqe", "q2b", "betae", "q2p", "fuzzqe"] {
        let mut c = cfg(model, 2);
        if ngdb_zoo::config::model_supports_negation(model) {
            c.patterns = Pattern::ALL.to_vec();
        }
        let mut state = state_for(&rt, model, &kg);
        let report = Trainer::new(&rt, Arc::clone(&kg), c)
            .train(&mut state)
            .unwrap_or_else(|e| panic!("{model}: {e:#}"));
        assert!(
            report.loss_curve.iter().all(|l| l.is_finite()),
            "{model}: {:?}",
            report.loss_curve
        );
    }
}

#[test]
fn batching_policies_agree_numerically_on_real_artifacts() {
    // operator-level fusion must not change the computed loss
    let rt = runtime();
    let kg = toy_kg();
    let mut rng = ngdb_zoo::util::rng::Rng::new(3);
    let mut queries = Vec::new();
    for p in [Pattern::P1, Pattern::P2, Pattern::I2, Pattern::Pi] {
        for _ in 0..4 {
            if let Some(mut q) = ngdb_zoo::sampler::ground(&kg, &mut rng, p) {
                q.negatives = ngdb_zoo::sampler::negatives(
                    &kg, &mut rng, q.answer, None, rt.manifest().dims.n_neg);
                queries.push(q);
            }
        }
    }
    let state = state_for(&rt, "gqe", &kg);
    let run = |singleton: bool| -> (f64, Grads) {
        let mut dag = QueryDag::default();
        for q in &queries {
            dag.add_query(&q.tree, q.answer, q.negatives.clone(), q.pattern.name(), false)
                .unwrap();
        }
        dag.add_gradient_nodes();
        let engine = Engine::new(
            &rt,
            EngineConfig { force_singleton: singleton, nan_check: true, ..Default::default() },
        );
        let mut grads = Grads::default();
        let stats = engine.run(&dag, &state, &mut grads).unwrap();
        (stats.loss, grads)
    };
    let (loss_batched, g_b) = run(false);
    let (loss_single, g_s) = run(true);
    let rel = (loss_batched - loss_single).abs() / loss_single.abs().max(1e-9);
    assert!(rel < 1e-3, "batched {loss_batched} vs singleton {loss_single}");
    // spot-check a few embedding gradients
    let mut checked = 0;
    for (k, v) in &g_b.ent {
        let w = &g_s.ent[k];
        for (a, b) in v.iter().zip(w) {
            assert!((a - b).abs() < 1e-2 * (1.0 + a.abs()), "ent {k}: {a} vs {b}");
        }
        checked += 1;
        if checked > 10 {
            break;
        }
    }
}

#[test]
fn betae_trains_negation_patterns() {
    let rt = runtime();
    let kg = toy_kg();
    let mut c = cfg("betae", 3);
    c.patterns = Pattern::NEGATION.to_vec();
    let mut state = state_for(&rt, "betae", &kg);
    let report = Trainer::new(&rt, Arc::clone(&kg), c).train(&mut state).unwrap();
    assert!(report.loss_curve.iter().all(|l| l.is_finite()));
}

#[test]
fn eval_mrr_improves_with_training() {
    let rt = runtime();
    let kg = toy_kg();
    let full = rank::full_graph(&kg).unwrap();
    let queries =
        rank::sample_eval_queries(&kg, &full, &[Pattern::P1, Pattern::I2], 12, 5);
    assert!(!queries.is_empty());
    let mut state = state_for(&rt, "gqe", &kg);
    let before = rank::evaluate(&rt, &state, &kg, &queries, None).unwrap();
    let mut c = cfg("gqe", 30);
    c.batch_queries = 128;
    Trainer::new(&rt, Arc::clone(&kg), c).train(&mut state).unwrap();
    let after = rank::evaluate(&rt, &state, &kg, &queries, None).unwrap();
    assert!(
        after.mrr > before.mrr,
        "training should improve MRR: {:.4} -> {:.4}",
        before.mrr,
        after.mrr
    );
}

#[test]
fn decoupled_and_joint_semantic_paths_agree() {
    let rt = runtime();
    let kg = toy_kg();
    let dims = rt.manifest().dims.clone();
    let desc = Arc::new(Descriptions::build(&kg, dims.tok_dim, 9));
    let joint = JointEncoder::new(&rt, "bge_sim", Arc::clone(&desc), &artifacts_dir()).unwrap();
    let cache = DecoupledCache::precompute(&rt, "bge_sim", &desc, &artifacts_dir()).unwrap();

    let mut state = state_for(&rt, "gqe", &kg);
    state.load_fusion(rt.manifest(), "bge_sim", Some(&artifacts_dir()), 1).unwrap();

    let tree = QueryTree::instantiate(Pattern::P2, &[3], &[0, 1]).unwrap();
    let run = |sem: &dyn ngdb_zoo::semantic::SemanticSource| -> Vec<f32> {
        let mut dag = QueryDag::default();
        let root = dag.add_query_eval(&tree, false).unwrap();
        let engine = Engine::with_semantic(&rt, EngineConfig::default(), sem);
        let mut grads = Grads::default();
        let (_, outs) = engine.run_with_outputs(&dag, &state, &mut grads, &[root]).unwrap();
        outs.into_iter().next().unwrap()
    };
    let a = run(&joint);
    let b = run(&cache);
    for (x, y) in a.iter().zip(&b) {
        assert!((x - y).abs() < 1e-4, "joint {x} vs decoupled {y}");
    }
    // decoupled keeps H_sem resident; joint keeps the encoder weights
    assert!(cache.resident_bytes() > 0);
    assert!(joint.resident_bytes() > cache.resident_bytes() / 64);
}

#[test]
fn semantic_trainer_runs_decoupled() {
    let rt = runtime();
    let kg = toy_kg();
    let dims = rt.manifest().dims.clone();
    let desc = Descriptions::build(&kg, dims.tok_dim, 9);
    let cache = DecoupledCache::precompute(&rt, "bge_sim", &desc, &artifacts_dir()).unwrap();
    let mut c = cfg("gqe", 3);
    c.semantic = Semantic::Decoupled { encoder: "bge_sim".into() };
    let mut state = state_for(&rt, "gqe", &kg);
    state.load_fusion(rt.manifest(), "bge_sim", Some(&artifacts_dir()), 1).unwrap();
    let report = Trainer::new(&rt, Arc::clone(&kg), c)
        .with_semantic(&cache)
        .train(&mut state)
        .unwrap();
    assert!(report.loss_curve.iter().all(|l| l.is_finite()));
    assert!(report.mem.resident_bytes > 0);
}

#[test]
fn complex_single_hop_epoch() {
    let rt = runtime();
    let kg = toy_kg();
    let mut state = ModelState::init(rt.manifest(), "complex", kg.n_entities,
        kg.n_relations, Some(&artifacts_dir()), 4).unwrap();
    let report =
        ngdb_zoo::train::train_complex(&rt, Arc::clone(&kg), &mut state, 2, 512, 1e-2, 3)
            .unwrap();
    assert_eq!(report.epoch_secs.len(), 2);
    assert!(report.triples_per_sec > 0.0);
    assert!(
        report.loss_curve[1] < report.loss_curve[0],
        "epoch loss should fall: {:?}",
        report.loss_curve
    );
}

#[test]
fn runtime_rejects_bad_shapes_and_unknown_artifacts() {
    let rt = runtime();
    let bad = ngdb_zoo::runtime::HostTensor::zeros(vec![3, 3]);
    assert!(rt.execute("gqe_embed_fwd_b16", &[bad]).is_err());
    assert!(rt.execute("not_a_thing", &[]).is_err());
}
