//! `dims.b_max_by_op` round-trip: manifest JSON → `Dims::b_max_for` →
//! `Engine::b_max` routing (observed through the artifacts the engine
//! launches), covering the empty-map fast path (no per-op lookups, global
//! cap everywhere) and a per-op override sourced from JSON.

use ngdb_zoo::exec::{Engine, EngineConfig, Grads};
use ngdb_zoo::model::ModelState;
use ngdb_zoo::query::{Pattern, QueryDag, QueryTree};
use ngdb_zoo::runtime::{Manifest, MockRuntime, Runtime};

/// A dims fragment in exactly the schema aot.py emits.
fn manifest_json(b_max_by_op: &str) -> String {
    format!(
        r#"{{
      "dims": {{"d": 4, "n_neg": 2, "buckets": [2, 4, 8], "b_max": 8,{b_max_by_op}
               "eval_b": 2, "eval_chunk": 4, "intersect_cards": [2, 3],
               "union_cards": [2], "tok_dim": 8, "gamma": 12.0,
               "use_pallas": false, "pte_bucket": 2, "ptes": {{}},
               "repr_dim": {{"mock": 4}}, "ent_dim": {{"mock": 4}},
               "rel_dim": {{"mock": 4}}}},
      "params": {{"models": {{"mock": []}}, "pte": {{}}, "fusion": {{}}}},
      "artifacts": []
    }}"#
    )
}

fn eight_p1_dag() -> QueryDag {
    let mut dag = QueryDag::default();
    for i in 0..8u32 {
        let tree = QueryTree::instantiate(Pattern::P1, &[i % 12], &[i % 6]).unwrap();
        dag.add_query(&tree, 3, vec![0, 1], Pattern::P1.name(), true).unwrap();
    }
    dag.add_gradient_nodes();
    dag
}

fn run(rt: &MockRuntime, dag: &QueryDag) {
    let st = ModelState::init(rt.manifest(), "mock", 12, 6, None, 3).unwrap();
    let engine = Engine::new(rt, EngineConfig::default());
    let mut grads = Grads::default();
    engine.run(dag, &st, &mut grads).unwrap();
}

#[test]
fn per_op_caps_round_trip_from_json_into_engine_routing() {
    // parse the JSON exactly as a real manifest.json would arrive …
    let parsed = Manifest::parse(&manifest_json(
        r#" "b_max_by_op": {"embed": 2, "score": 99},"#,
    ))
    .unwrap();
    assert_eq!(parsed.dims.b_max_for("embed"), 2);
    assert_eq!(parsed.dims.b_max_for("score"), 8, "overrides clamp to the global cap");
    assert_eq!(parsed.dims.b_max_for("project"), 8, "absent ops fall back");

    // … and route the parsed caps through a live engine: 8 ready embeds
    // under a cap of 2 must launch the b=2 artifact 4 times while projects
    // keep the global cap (one b=8 launch).
    let mut rt = MockRuntime::new();
    for (op, cap) in &parsed.dims.b_max_by_op {
        rt.set_b_max_for(op, *cap);
    }
    run(&rt, &eight_p1_dag());
    assert_eq!(rt.calls_of("mock_embed_fwd_b2"), 4);
    assert_eq!(rt.calls_of("mock_embed_fwd_b8"), 0);
    assert_eq!(rt.calls_of("mock_project_fwd_b8"), 1);
}

#[test]
fn missing_map_takes_the_empty_fast_path() {
    // aot.py omits the key entirely when no op needs a custom cap: the
    // parsed map must be empty (the engine then skips per-op lookups —
    // `Engine::b_max` reads `dims.b_max` without allocating an op name)
    // and every pool batches at the global cap.
    let parsed = Manifest::parse(&manifest_json("")).unwrap();
    assert!(parsed.dims.b_max_by_op.is_empty());
    assert_eq!(parsed.dims.b_max_for("embed"), 8);

    let rt = MockRuntime::new();
    assert!(rt.manifest().dims.b_max_by_op.is_empty());
    run(&rt, &eight_p1_dag());
    assert_eq!(rt.calls_of("mock_embed_fwd_b8"), 1, "uncapped: one fused launch");
    assert_eq!(rt.calls_of("mock_embed_fwd_b2"), 0);
}
