//! Mmap-parity suite: answers served out of a memory-mapped checkpoint
//! generation must be *bitwise* indistinguishable from a heap capture of
//! the same weights — for every shard count, every worker count, through
//! the portable no-mmap fallback, and after a kill-and-recover restart
//! over a torn generation.
//!
//! CI runs this file serially in the stress job: the fallback leg flips
//! the process-wide `NGDB_NO_MMAP` knob, which must not race other opens.

use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Duration;

use ngdb_zoo::model::{ModelSnapshot, ModelState, SnapshotCell};
use ngdb_zoo::query::{Pattern, QueryTree};
use ngdb_zoo::runtime::{MockRuntime, Runtime};
use ngdb_zoo::serve::{
    snapshot_cell_for, QueryAnswer, QueryRequest, QueryService, ServeConfig, SnapshotBacking,
};
use ngdb_zoo::train::{CheckpointConfig, CheckpointStore, CkptError, SaveKind};

const SHARD_SWEEP: [usize; 4] = [1, 2, 4, 7];
const N_ENT: usize = 24;
const N_REL: usize = 6;

fn tmp(name: &str) -> PathBuf {
    let p = std::env::temp_dir().join(format!("ngdb_mmap_parity_{name}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&p); // stale layouts from prior runs
    p
}

fn state(seed: u64) -> ModelState {
    let rt = MockRuntime::new();
    ModelState::init(rt.manifest(), "mock", N_ENT, N_REL, None, seed).unwrap()
}

fn store_at(dir: &Path, n_shards: usize) -> CheckpointStore {
    CheckpointStore::open(dir)
        .with_config(CheckpointConfig { serve_layout: Some(n_shards), ..Default::default() })
}

/// Serve the fixed request mix (the same one `shard_parity` sweeps:
/// P1/P2/I2 trees, filters, k across shard-boundary shapes) off `cell`.
fn answers_for(cell: Arc<SnapshotCell>, workers: usize) -> Vec<QueryAnswer> {
    let rt = Arc::new(MockRuntime::new());
    let service = QueryService::start(
        rt,
        cell,
        ServeConfig {
            workers,
            max_batch: 8,
            max_wait: Duration::from_millis(2),
            ..Default::default()
        },
    );
    let client = service.client();
    let reqs: Vec<QueryRequest> = (0..18u32)
        .map(|i| {
            let (e, r) = (N_ENT as u32, N_REL as u32);
            let tree = match i % 3 {
                0 => QueryTree::instantiate(Pattern::P1, &[i % e], &[i % r]).unwrap(),
                1 => QueryTree::instantiate(Pattern::P2, &[(i + 7) % e], &[i % r, (i + 1) % r])
                    .unwrap(),
                _ => QueryTree::instantiate(
                    Pattern::I2,
                    &[i % e, (i + 5) % e],
                    &[i % r, (i + 2) % r],
                )
                .unwrap(),
            };
            QueryRequest { tree, filter: vec![i % e, (i + 3) % e], top_k: 1 + (i as usize % 23) }
        })
        .collect();
    let pending: Vec<_> = reqs.iter().map(|r| client.submit(r.clone()).unwrap()).collect();
    let answers = pending.into_iter().map(|p| p.wait().unwrap()).collect();
    drop(client);
    service.shutdown();
    answers
}

fn assert_bitwise(got: &[QueryAnswer], want: &[QueryAnswer], ctx: &str) {
    assert_eq!(got.len(), want.len(), "{ctx}");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        assert_eq!(g.top.len(), w.top.len(), "req {i}: answer width drifted ({ctx})");
        for ((ge, gs), (we, ws)) in g.top.iter().zip(&w.top) {
            assert_eq!(ge, we, "req {i}: entity ids diverged ({ctx})");
            assert_eq!(gs.to_bits(), ws.to_bits(), "req {i}: score bits drifted ({ctx})");
        }
    }
}

/// Touch `rows` of the live entity table and record them dirty, the way
/// an optimizer step would.
fn mutate(live: &mut ModelState, rows: &[u32], delta: f32) {
    let dim = live.entities.dim;
    for &row in rows {
        for x in &mut live.entities.data[row as usize * dim..(row as usize + 1) * dim] {
            *x += delta;
        }
        live.dirty.ent.insert(row);
    }
}

/// The headline guarantee: a worker fleet mapping one serve-layout file
/// answers exactly what a fleet of heap copies answers — for every shard
/// count and worker count, with zero snapshot bytes on the heap.
#[test]
fn mapped_serving_is_bitwise_identical_for_every_shard_and_worker_count() {
    let mut live = state(11);
    live.step = 1;
    for n_shards in SHARD_SWEEP {
        let dir = tmp(&format!("sweep_{n_shards}"));
        store_at(&dir, n_shards).save(&live).unwrap();
        let heap = snapshot_cell_for(&SnapshotBacking::Heap, &live, n_shards, None).unwrap();
        let mapped =
            snapshot_cell_for(&SnapshotBacking::MappedFrom(dir.clone()), &live, n_shards, None)
                .unwrap();
        {
            let snap = mapped.load();
            assert!(snap.is_mapped(), "shards={n_shards}: tables must be file windows");
            assert_eq!(snap.heap_bytes(), 0, "shards={n_shards}: no private copies");
        }
        let reference = answers_for(heap, 1);
        assert!(reference.iter().any(|a| a.top.len() > 4), "degenerate reference");
        for workers in [1usize, 2] {
            let got = answers_for(Arc::clone(&mapped), workers);
            assert_bitwise(&got, &reference, &format!("shards={n_shards} workers={workers}"));
        }
        std::fs::remove_dir_all(&dir).ok();
    }
}

/// `NGDB_NO_MMAP=1` swaps the OS mapping for the portable heap decode of
/// the same serve file — the answers must not move by a bit.
#[test]
fn the_portable_no_mmap_fallback_decodes_identical_answers() {
    let mut live = state(13);
    live.step = 1;
    let dir = tmp("fallback");
    store_at(&dir, 4).save(&live).unwrap();
    let reference =
        answers_for(snapshot_cell_for(&SnapshotBacking::Heap, &live, 4, None).unwrap(), 1);
    std::env::set_var("NGDB_NO_MMAP", "1");
    let cell = snapshot_cell_for(&SnapshotBacking::MappedFrom(dir.clone()), &live, 4, None);
    std::env::remove_var("NGDB_NO_MMAP");
    let got = answers_for(cell.unwrap(), 2);
    assert_bitwise(&got, &reference, "no-mmap fallback");
    std::fs::remove_dir_all(&dir).ok();
}

/// Kill-and-recover: a base + delta chain with a torn (uncommitted)
/// generation on top. A restarted process recovers the heap state and
/// maps the same chain — journaled rows materialize on heap pages, clean
/// pages stay mapped, and both backings serve identical bits.
#[test]
fn recovery_after_a_torn_commit_serves_mapped_bitwise() {
    let dir = tmp("recover");
    let mut live = state(17);
    let mut store = store_at(&dir, 4);
    live.step = 1;
    store.save(&live).unwrap();
    for k in 0..2u64 {
        let rows: Vec<u32> =
            (0..3u64).map(|i| ((k * 5 + i * 7) % N_ENT as u64) as u32).collect();
        mutate(&mut live, &rows, 0.25 + k as f32);
        live.step += 1;
        store.absorb_dirty(&live.dirty);
        live.dirty.reset_to(live.step);
        assert_eq!(store.save(&live).unwrap().kind, SaveKind::Delta);
    }
    // a writer killed mid-commit leaves a generation directory with no
    // committed manifest; recovery (heap and mapped alike) must skip it
    let torn = dir.join("gen-000009");
    std::fs::create_dir_all(&torn).unwrap();
    std::fs::write(torn.join("ent.data.bin"), b"torn").unwrap();

    // "restart": a fresh process recovers the latest committed chain
    let mut recovered = state(1);
    let gen = CheckpointStore::open(&dir).load_latest(&mut recovered).unwrap();
    assert_eq!(gen, 3, "the torn generation must not win recovery");
    let heap = Arc::new(SnapshotCell::new(ModelSnapshot::capture_sharded(&recovered, 4)));
    let mapped =
        snapshot_cell_for(&SnapshotBacking::MappedFrom(dir.clone()), &recovered, 4, None).unwrap();
    {
        let snap = mapped.load();
        assert_eq!(snap.step(), recovered.step);
        assert!(snap.entities().heap_bytes() > 0, "journaled rows materialize on heap");
        assert!(snap.mapped_bytes() > 0, "clean pages stay mapped");
    }
    let reference = answers_for(heap, 1);
    for workers in [1usize, 2] {
        let got = answers_for(Arc::clone(&mapped), workers);
        assert_bitwise(&got, &reference, &format!("recovered workers={workers}"));
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// The trainer keeps publishing COW deltas on top of a mapped snapshot:
/// dirty pages materialize on the heap, clean pages keep referencing the
/// checkpoint file (counted by `remaps`), and the published weights stay
/// bitwise identical to a fresh full capture.
#[test]
fn delta_publishes_over_mapped_pages_count_remaps_and_stay_bitwise() {
    let dir = tmp("remap");
    let mut live = state(19);
    live.step = 1;
    store_at(&dir, 4).save(&live).unwrap();
    let cell =
        snapshot_cell_for(&SnapshotBacking::MappedFrom(dir.clone()), &live, 4, None).unwrap();
    // the mapped snapshot is this step's delta baseline
    live.dirty.reset_to(live.step);
    mutate(&mut live, &[2, 9, 14], -0.75);
    live.step += 1;
    cell.publish_from(&mut live, None);
    let totals = cell.publish_totals();
    assert_eq!((totals.delta_publishes, totals.remaps), (1, 1), "{totals:?}");

    let snap = cell.load();
    assert!(snap.is_mapped(), "clean pages must stay mapped after the delta");
    assert!(snap.heap_bytes() > 0, "dirty pages materialize on the heap");
    let full = ModelSnapshot::capture_sharded(&live, 4);
    let (a, b) = (snap.entities().to_flat(), full.entities().to_flat());
    for (i, (x, y)) in a.iter().zip(&b).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "entity weight {i} diverged post-delta");
    }
    let reference = answers_for(Arc::new(SnapshotCell::new(full)), 1);
    let got = answers_for(cell, 2);
    assert_bitwise(&got, &reference, "post-delta mapped");
    std::fs::remove_dir_all(&dir).ok();
}

/// Misconfiguration is a typed refusal, never a silent heap fallback: a
/// root whose newest generation carries no serve layout must not serve.
#[test]
fn mapped_backing_refuses_roots_without_a_serve_layout() {
    let dir = tmp("refuse");
    let mut live = state(23);
    live.step = 1;
    // a plain store (no serve_layout) commits a valid but unmapped gen
    CheckpointStore::open(&dir).save(&live).unwrap();
    let err = snapshot_cell_for(&SnapshotBacking::MappedFrom(dir.clone()), &live, 4, None)
        .unwrap_err();
    assert!(matches!(err, CkptError::Incompatible { .. }), "{err}");
    assert!(err.to_string().contains("serve layout"), "{err}");
    std::fs::remove_dir_all(&dir).ok();
}
