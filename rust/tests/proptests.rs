//! Property tests over coordinator invariants (mock runtime — no
//! artifacts needed, fast). Complements the unit-level properties inside
//! each module with cross-module algebraic laws.

use std::sync::Arc;

use ngdb_zoo::eval::symbolic::answers;
use ngdb_zoo::exec::{Engine, EngineConfig, Grads};
use ngdb_zoo::kg::{KgSpec, KgStore, Triple};
use ngdb_zoo::model::ModelState;
use ngdb_zoo::query::{Pattern, QueryDag, QueryTree};
use ngdb_zoo::runtime::{MockRuntime, Runtime};
use ngdb_zoo::sampler::ground;
use ngdb_zoo::util::proptest::queries::{self, QuerySet};
use ngdb_zoo::util::proptest::{gen, prop_check, prop_check_shrink};
use ngdb_zoo::util::rng::Rng;

fn random_kg(rng: &mut Rng) -> KgStore {
    let n_ent = gen::size(rng, 8, 60);
    let n_rel = gen::size(rng, 2, 6);
    let n_edges = gen::size(rng, n_ent, n_ent * 4);
    let mut triples = Vec::new();
    let mut seen = std::collections::HashSet::new();
    for _ in 0..n_edges * 3 {
        if triples.len() >= n_edges {
            break;
        }
        let h = rng.below(n_ent) as u32;
        let t = rng.below(n_ent) as u32;
        let r = rng.below(n_rel) as u32;
        if h != t && seen.insert((h, r, t)) {
            triples.push(Triple { h, r, t });
        }
    }
    KgStore::new("prop", n_ent, n_rel, triples, vec![], vec![]).unwrap()
}

#[test]
fn intersection_is_subset_of_branches_and_union_superset() {
    prop_check("set-operator algebra", 60, |rng| {
        let kg = random_kg(rng);
        let mk = |rng: &mut Rng| {
            QueryTree::Project(
                Box::new(QueryTree::Anchor(rng.below(kg.n_entities) as u32)),
                rng.below(kg.n_relations) as u32,
            )
        };
        let (a, b) = (mk(rng), mk(rng));
        let ia = answers(&kg, &a).map_err(|e| e.to_string())?;
        let ib = answers(&kg, &b).map_err(|e| e.to_string())?;
        let inter = answers(&kg, &QueryTree::Intersect(vec![a.clone(), b.clone()]))
            .map_err(|e| e.to_string())?;
        let uni = answers(&kg, &QueryTree::Union(vec![a.clone(), b.clone()]))
            .map_err(|e| e.to_string())?;
        for x in &inter {
            if ia.binary_search(x).is_err() || ib.binary_search(x).is_err() {
                return Err(format!("{x} in A∩B but not in both branches"));
            }
        }
        for x in ia.iter().chain(&ib) {
            if uni.binary_search(x).is_err() {
                return Err(format!("{x} in a branch but missing from A∪B"));
            }
        }
        // |A∪B| = |A| + |B| - |A∩B|
        if uni.len() + inter.len() != ia.len() + ib.len() {
            return Err("inclusion-exclusion violated".into());
        }
        Ok(())
    });
}

#[test]
fn negation_never_contains_negated_branch() {
    prop_check("¬ branch excluded from 2in answers", 40, |rng| {
        let kg = random_kg(rng);
        let Some(q) = ground(&kg, rng, Pattern::In2) else { return Ok(()) };
        let ans = answers(&kg, &q.tree).map_err(|e| e.to_string())?;
        let QueryTree::Intersect(branches) = &q.tree else {
            return Err("2in must lower to an intersection".into());
        };
        let neg = branches
            .iter()
            .find_map(|b| match b {
                QueryTree::Negate(inner) => Some(inner.as_ref()),
                _ => None,
            })
            .ok_or("missing negated branch")?;
        let neg_ans = answers(&kg, neg).map_err(|e| e.to_string())?;
        for x in &ans {
            if neg_ans.binary_search(x).is_ok() {
                return Err(format!("{x} survives its own negation"));
            }
        }
        Ok(())
    });
}

#[test]
fn grounded_answer_is_always_in_answer_set() {
    prop_check("sampler soundness across patterns/graphs", 40, |rng| {
        let kg = random_kg(rng);
        let p = *rng.choice(&Pattern::ALL);
        let Some(q) = ground(&kg, rng, p) else { return Ok(()) };
        let ans = answers(&kg, &q.tree).map_err(|e| e.to_string())?;
        if ans.binary_search(&q.answer).is_err() {
            return Err(format!("{p}: grounded answer not in A_q"));
        }
        Ok(())
    });
}

#[test]
fn batched_equals_query_level_equals_singleton_loss() {
    // all three batching granularities must compute the same numbers; on a
    // counterexample the shared QuerySet shrinker minimizes the workload
    let rt = MockRuntime::new();
    let state = ModelState::init(rt.manifest(), "mock", 64, 8, None, 3).unwrap();
    let kg = queries::toy_kg();
    prop_check_shrink(
        "scheduling-policy numerics invariance",
        15,
        |rng| {
            queries::random_set(
                rng,
                &kg,
                &[Pattern::P1, Pattern::P2, Pattern::I2, Pattern::Up],
                12,
                64,
                8,
                2,
            )
        },
        QuerySet::shrink,
        |set| {
            if set.is_empty() {
                return Ok(());
            }
            let engine = Engine::new(&rt, EngineConfig::default());
            let mut g_all = Grads::default();
            engine.run(&set.train_dag(), &state, &mut g_all).map_err(|e| e.to_string())?;
            let mut g_sep = Grads::default();
            for q in &set.0 {
                let one = QuerySet(vec![q.clone()]);
                engine.run(&one.train_dag(), &state, &mut g_sep).map_err(|e| e.to_string())?;
            }
            if (g_all.loss - g_sep.loss).abs() > 1e-4 * (1.0 + g_sep.loss.abs()) {
                return Err(format!("loss mismatch {} vs {}", g_all.loss, g_sep.loss));
            }
            for (k, v) in &g_all.ent {
                let w = g_sep.ent.get(k).ok_or(format!("missing ent grad {k}"))?;
                for (a, b) in v.iter().zip(w) {
                    if (a - b).abs() > 1e-4 {
                        return Err(format!("ent {k} grad {a} vs {b}"));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn adjacency_matches_bruteforce() {
    prop_check("CSR neighbors == brute-force scan", 40, |rng| {
        let kg = random_kg(rng);
        for _ in 0..20 {
            let h = rng.below(kg.n_entities) as u32;
            let r = rng.below(kg.n_relations) as u32;
            let mut want: Vec<u32> = kg
                .train
                .iter()
                .filter(|t| t.h == h && t.r == r)
                .map(|t| t.t)
                .collect();
            want.sort_unstable();
            let got: Vec<u32> = kg.tails(h, r).collect();
            if got != want {
                return Err(format!("tails({h},{r}): {got:?} != {want:?}"));
            }
        }
        Ok(())
    });
}

#[test]
fn multi_worker_gradients_match_single_worker_totals() {
    // merging shard gradients must equal running the shards in one engine
    prop_check("all-reduce equivalence", 10, |rng| {
        let rt = MockRuntime::new();
        let state = ModelState::init(rt.manifest(), "mock", 32, 4, None, 1).unwrap();
        let kg = queries::toy_kg();
        let n = gen::size(rng, 2, 8);
        let mut qs = Vec::new();
        for _ in 0..n {
            if let Some(q) = ground(&kg, rng, Pattern::P1) {
                qs.push((queries::remap_tree(&q.tree, 32, 4), q.answer % 32));
            }
        }
        if qs.len() < 2 {
            return Ok(());
        }
        let engine = Engine::new(&rt, EngineConfig::default());
        // "two workers": split in half, merge grads
        let mut merged = Grads::default();
        for half in qs.chunks(qs.len().div_ceil(2)) {
            let mut dag = QueryDag::default();
            for (t, a) in half {
                dag.add_query(t, *a, vec![0, 1], "1p", true).unwrap();
            }
            dag.add_gradient_nodes();
            engine.run(&dag, &state, &mut merged).map_err(|e| e.to_string())?;
        }
        // "one worker": all at once
        let mut dag = QueryDag::default();
        for (t, a) in &qs {
            dag.add_query(t, *a, vec![0, 1], "1p", true).unwrap();
        }
        dag.add_gradient_nodes();
        let mut single = Grads::default();
        engine.run(&dag, &state, &mut single).map_err(|e| e.to_string())?;

        if (merged.loss - single.loss).abs() > 1e-4 {
            return Err(format!("loss {} vs {}", merged.loss, single.loss));
        }
        for (k, v) in &single.ent {
            let w = merged.ent.get(k).ok_or(format!("missing {k}"))?;
            for (a, b) in v.iter().zip(w) {
                if (a - b).abs() > 1e-4 {
                    return Err(format!("grad {k}: {a} vs {b}"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn empty_and_degenerate_dags_are_handled() {
    let rt = MockRuntime::new();
    let state = ModelState::init(rt.manifest(), "mock", 8, 2, None, 1).unwrap();
    let engine = Engine::new(&rt, EngineConfig::default());
    // empty DAG: nothing to do, no panic
    let dag = QueryDag::default();
    let mut grads = Grads::default();
    let stats = engine.run(&dag, &state, &mut grads).unwrap();
    assert_eq!(stats.operators, 0);
    // eval-only DAG (no score node)
    let mut dag = QueryDag::default();
    let tree = QueryTree::instantiate(Pattern::P1, &[1], &[0]).unwrap();
    let root = dag.add_query_eval(&tree, true).unwrap();
    let (_, outs) = engine
        .run_with_outputs(&dag, &state, &mut grads, &[root])
        .unwrap();
    assert_eq!(outs.len(), 1);
}

#[test]
fn fused_dag_pools_share_across_queries() {
    // Arc-level check that cross-query fusion actually happens: N 1p
    // queries -> ~1 embed launch, ~1 project launch, ~1 score launch.
    let rt = MockRuntime::new();
    let state = ModelState::init(rt.manifest(), "mock", 32, 4, None, 1).unwrap();
    let mut dag = QueryDag::default();
    for i in 0..8u32 {
        let tree = QueryTree::instantiate(Pattern::P1, &[i % 32], &[i % 4]).unwrap();
        dag.add_query(&tree, (i + 1) % 32, vec![0, 1], "1p", true).unwrap();
    }
    dag.add_gradient_nodes();
    let engine = Engine::new(&rt, EngineConfig::default());
    let mut grads = Grads::default();
    let stats = engine.run(&dag, &state, &mut grads).unwrap();
    // 5 op types (embed, project, score, vjp_project, vjp_embed) and 8
    // queries -> exactly 5 launches if fusion is perfect
    assert_eq!(stats.executions, 5, "fusion should hit one launch per type");
    assert_eq!(stats.operators, dag.len());
}

#[test]
fn sampler_stream_is_arc_safe_under_shutdown_races() {
    // failure injection: shutdown while producers are mid-grounding
    for seed in 0..5 {
        let kg: Arc<KgStore> =
            Arc::new(KgSpec::preset("toy", 1.0).unwrap().generate().unwrap());
        let s = ngdb_zoo::sampler::SamplerStream::spawn(
            kg,
            ngdb_zoo::sampler::SamplerConfig {
                threads: 2,
                queue_depth: 4,
                seed,
                ..Default::default()
            },
        );
        let _ = s.recv_batch(2);
        s.shutdown(); // must not deadlock or panic
    }
}
