//! Scheduler-equivalence property suite: the pipelined engine (persistent
//! gather worker + speculation, overlap active under semantic fusion) must
//! be **indistinguishable** from the synchronous engine — same round
//! schedule, same fillness trace, bit-identical loss and gradients — across
//! every configuration axis:
//!
//! * randomized query DAGs (shared shrinking generator in
//!   `util::proptest::queries`);
//! * per-operator `B_max` caps (`dims.b_max_by_op` routing);
//! * slow-execute vs instant-execute MockRuntime timings;
//! * semantic fusion off / on (pure table source and joint-style
//!   encoder-executing source);
//! * forced mis-speculation (constructed pool flips);
//! * per-run engines vs a reused `EngineSession` (every case also runs its
//!   DAG twice through one warm session and diffs both runs bitwise).
//!
//! `NGDB_STRESS=1` (the CI forced-contention job, run with
//! `--test-threads=1`) widens the timing matrix so gathers and executes
//! race in both directions, and multiplies the case counts.

use std::sync::atomic::Ordering;
use std::time::Duration;

use ngdb_zoo::exec::{Engine, EngineConfig, EngineSession, Grads, StepStats};
use ngdb_zoo::model::ModelState;
use ngdb_zoo::query::{Pattern, QueryDag, QueryTree};
use ngdb_zoo::runtime::mock::max_call_depth;
use ngdb_zoo::runtime::{MockRuntime, Runtime};
use ngdb_zoo::semantic::mock::{EncoderSource, TableSource};
use ngdb_zoo::semantic::SemanticSource;
use ngdb_zoo::util::proptest::queries::{self, QuerySet};
use ngdb_zoo::util::proptest::{gen, prop_check_shrink};
use ngdb_zoo::util::rng::Rng;

const NE: usize = 12; // mock entity rows
const NR: usize = 6; // mock relation rows
const NEG: usize = 2; // mock n_neg

fn stress() -> bool {
    std::env::var("NGDB_STRESS").as_deref() == Ok("1")
}

fn mock_state(rt: &MockRuntime) -> ModelState {
    ModelState::init(rt.manifest(), "mock", NE, NR, None, 3).unwrap()
}

/// Run one engine configuration and return its telemetry + gradients.
fn run_one(
    rt: &MockRuntime,
    dag: &QueryDag,
    st: &ModelState,
    cfg: EngineConfig,
    semantic: Option<&dyn SemanticSource>,
) -> Result<(StepStats, Grads), String> {
    let engine = match semantic {
        Some(s) => Engine::with_semantic(rt, cfg, s),
        None => Engine::new(rt, cfg),
    };
    let mut grads = Grads::default();
    let stats = engine.run(dag, st, &mut grads).map_err(|e| format!("{e:#}"))?;
    Ok((stats, grads))
}

/// Bit-exact comparison of two runs: schedule, fillness, loss bits, and
/// every gradient entry (`f32::to_bits`). Returns the first divergence.
fn assert_equivalent(
    (s_a, g_a): &(StepStats, Grads),
    (s_b, g_b): &(StepStats, Grads),
) -> Result<(), String> {
    if s_a.executions != s_b.executions {
        return Err(format!("round counts: {} vs {}", s_a.executions, s_b.executions));
    }
    if s_a.schedule != s_b.schedule {
        return Err(format!("schedules diverge: {:?} vs {:?}", s_a.schedule, s_b.schedule));
    }
    if s_a.fillness != s_b.fillness {
        return Err("fillness traces diverge".into());
    }
    if s_a.loss.to_bits() != s_b.loss.to_bits() {
        return Err(format!("loss not bit-identical: {} vs {}", s_a.loss, s_b.loss));
    }
    for (map_a, map_b, tag) in
        [(&g_a.ent, &g_b.ent, "ent"), (&g_a.rel, &g_b.rel, "rel")]
    {
        if map_a.len() != map_b.len() {
            return Err(format!("{tag} key counts: {} vs {}", map_a.len(), map_b.len()));
        }
        for (k, v) in map_a {
            let w = map_b.get(k).ok_or_else(|| format!("{tag} missing key {k}"))?;
            for (i, (x, y)) in v.iter().zip(w).enumerate() {
                if x.to_bits() != y.to_bits() {
                    return Err(format!("{tag}[{k}][{i}]: {x} vs {y} (bits differ)"));
                }
            }
        }
    }
    if g_a.dense.len() != g_b.dense.len() {
        return Err("dense key counts differ".into());
    }
    for (k, v) in &g_a.dense {
        let w = g_b.dense.get(k).ok_or_else(|| format!("dense missing key {k}"))?;
        for (i, (x, y)) in v.iter().zip(w).enumerate() {
            if x.to_bits() != y.to_bits() {
                return Err(format!("dense[{k}][{i}]: {x} vs {y} (bits differ)"));
            }
        }
    }
    Ok(())
}

/// One sampled engine/runtime configuration of the equivalence matrix.
#[derive(Clone, Debug)]
struct EquivCase {
    set: QuerySet,
    /// per-op caps applied to the mock manifest (op name, cap)
    caps: Vec<(&'static str, usize)>,
    /// global override through `EngineConfig::b_max` (0 = off)
    b_max: usize,
    /// artificial per-launch latency (slow-execute regime)
    delay_ms: u64,
    /// 0 = no fusion, 1 = pure table source, 2 = encoder-executing source
    fusion: u8,
    /// arena recycling on (the default hot path) or off (the pre-pool
    /// baseline) — every case also cross-checks the flipped setting
    pooling: bool,
}

fn build_runtime(case: &EquivCase) -> MockRuntime {
    let mut rt = MockRuntime::new();
    for (op, cap) in &case.caps {
        rt.set_b_max_for(op, *cap);
    }
    if case.delay_ms > 0 {
        rt = rt.with_exec_delay(Duration::from_millis(case.delay_ms));
    }
    rt
}

/// Build the semantic source selected by a case's `fusion` axis and hand it
/// to `f` (closure shape keeps the borrow of the temporaries simple):
/// 0 = none, 1 = pure table source, 2 = encoder-executing source.
fn with_fusion_source<R>(
    rt: &MockRuntime,
    fusion: u8,
    f: impl FnOnce(Option<&dyn SemanticSource>) -> R,
) -> R {
    match fusion {
        0 => f(None),
        1 => f(Some(&TableSource::linear(NE, rt.manifest().dims.d))),
        _ => f(Some(&EncoderSource::new(rt, NE))),
    }
}

fn check_case(case: &EquivCase) -> Result<(), String> {
    if case.set.is_empty() {
        return Ok(());
    }
    let rt = build_runtime(case);
    let st = mock_state(&rt);
    let dag = case.set.train_dag();
    let cfg = |pipeline: bool| EngineConfig {
        b_max: case.b_max,
        pipeline,
        pooling: case.pooling,
        ..Default::default()
    };

    with_fusion_source(&rt, case.fusion, |semantic| {
        let pipe = run_one(&rt, &dag, &st, cfg(true), semantic)?;
        let sync = run_one(&rt, &dag, &st, cfg(false), semantic)?;
        assert_equivalent(&pipe, &sync)?;
        if pipe.0.operators != dag.len() {
            return Err(format!("executed {} of {} operators", pipe.0.operators, dag.len()));
        }
        // session-reuse leg: the same DAG twice through ONE warm session
        // must match the per-run engines bit for bit on both runs — the
        // worker, channels, the tensor pool and the repr slab are
        // run-invariant (the second run executes entirely from recycled
        // buffers when pooling is on)
        let mut session = match semantic {
            Some(s) => EngineSession::with_semantic(&rt, cfg(true), s),
            None => EngineSession::new(&rt, cfg(true)),
        };
        for rep in 0..2 {
            let mut grads = Grads::default();
            let stats = session
                .run(&dag, &st, &mut grads)
                .map_err(|e| format!("session run {rep}: {e:#}"))?;
            assert_equivalent(&(stats, grads), &sync)
                .map_err(|e| format!("session run {rep}: {e}"))?;
        }
        // pooling cross-check: flipping the recycler must not change a bit
        let flipped = EngineConfig {
            b_max: case.b_max,
            pipeline: true,
            pooling: !case.pooling,
            ..Default::default()
        };
        let other = run_one(&rt, &dag, &st, flipped, semantic)?;
        assert_equivalent(&other, &sync)
            .map_err(|e| format!("pooling={} leg: {e}", !case.pooling))?;
        Ok(())
    })
}

#[test]
fn pipelined_equals_sync_across_the_configuration_matrix() {
    let kg = queries::toy_kg();
    let cap_ops: [&'static str; 4] = ["embed", "project", "score", "vjp_project"];
    let cases = if stress() { 60 } else { 25 };
    prop_check_shrink(
        "scheduler equivalence (caps × timing × fusion)",
        cases,
        |rng| {
            let set = queries::random_set(
                rng,
                &kg,
                &Pattern::ALL,
                if stress() { 32 } else { 16 },
                NE as u32,
                NR as u32,
                NEG,
            );
            let mut caps = Vec::new();
            for op in cap_ops {
                if rng.chance(0.3) {
                    caps.push((op, gen::size(rng, 1, 4)));
                }
            }
            let b_max = if rng.chance(0.25) { gen::size(rng, 1, 8) } else { 0 };
            // slow-execute rounds are expensive; sample them sparsely, and
            // only under stress make them common (forced contention)
            let delay_ms =
                if stress() && rng.chance(0.5) { 1 } else { u64::from(rng.chance(0.1)) };
            let fusion = rng.below(3) as u8;
            let pooling = !rng.chance(0.25);
            EquivCase { set, caps, b_max, delay_ms, fusion, pooling }
        },
        |case| {
            // shrink the workload only; the config axes stay fixed so the
            // minimal counterexample still reproduces the same regime
            case.set
                .shrink()
                .into_iter()
                .map(|set| EquivCase { set, ..case.clone() })
                .collect()
        },
        check_case,
    );
}

/// Workload that *guarantees* a mis-speculation: round 1 pops B_max embeds
/// and speculates on the leftovers, but completing round 1 readies a
/// project pool that out-fills them — the prefetch must be discarded
/// without changing a bit, with and without fusion.
fn mis_spec_set() -> QuerySet {
    let specs = (0..10)
        .map(|i| {
            let tree =
                QueryTree::instantiate(Pattern::P1, &[i % NE as u32], &[i % NR as u32]).unwrap();
            queries::QuerySpec {
                pattern: Pattern::P1,
                tree,
                answer: 3,
                negatives: vec![0, 1],
            }
        })
        .collect();
    QuerySet(specs)
}

#[test]
fn forced_mis_speculation_is_absorbed_with_and_without_fusion() {
    for fusion in [0u8, 1, 2] {
        let case = EquivCase {
            set: mis_spec_set(),
            caps: vec![],
            b_max: 0,
            delay_ms: 0,
            fusion,
            pooling: true,
        };
        let rt = build_runtime(&case);
        let st = mock_state(&rt);
        let dag = case.set.train_dag();
        with_fusion_source(&rt, fusion, |semantic| {
            let pipe = run_one(&rt, &dag, &st, EngineConfig::default(), semantic).unwrap();
            assert!(
                pipe.0.spec_misses >= 1,
                "fusion={fusion}: expected a forced mis-speculation, hits={} misses={}",
                pipe.0.spec_hits,
                pipe.0.spec_misses
            );
            let sync = run_one(
                &rt,
                &dag,
                &st,
                EngineConfig { pipeline: false, ..Default::default() },
                semantic,
            )
            .unwrap();
            assert_equivalent(&pipe, &sync).unwrap();
        });
    }
}

#[test]
fn joint_style_fusion_respects_the_concurrency_contract_under_load() {
    // Encoder-executing gathers overlapping slow round executions on a
    // runtime that reports concurrent execute UNSAFE: the gated submission
    // path must serialize everything (zero contract violations, strictly
    // depth-1 interleaving log) while the numbers stay bit-identical to
    // sync.
    let mut rt =
        MockRuntime::new().with_exec_delay(Duration::from_millis(2)).with_call_log();
    rt.set_concurrent_execute_safe(false);
    let st = mock_state(&rt);
    let encoder = EncoderSource::new(&rt, NE);
    let dag = mis_spec_set().train_dag();
    let pipe = run_one(&rt, &dag, &st, EngineConfig::default(), Some(&encoder)).unwrap();
    assert!(pipe.0.spec_hits + pipe.0.spec_misses > 0, "overlap must be exercised");
    let sync = run_one(
        &rt,
        &dag,
        &st,
        EngineConfig { pipeline: false, ..Default::default() },
        Some(&encoder),
    )
    .unwrap();
    assert_equivalent(&pipe, &sync).unwrap();
    assert_eq!(
        rt.contract_violations.load(Ordering::SeqCst),
        0,
        "no execute may enter while another is in flight on an unsafe backend"
    );
    let log = rt.take_call_log();
    assert!(!log.is_empty(), "call log must have recorded the runs");
    assert_eq!(
        max_call_depth(&log),
        1,
        "encoder gathers must serialize against round executions"
    );
}

#[test]
fn contention_counters_are_consistent() {
    // Heavy gathers + instant executes: the main thread should sometimes
    // block on unfinished prefetches; the counters must stay within the
    // stage totals they attribute.
    let rt = MockRuntime::new();
    let st = mock_state(&rt);
    let mut rng = Rng::new(7);
    let kg = queries::toy_kg();
    let set = queries::random_set(&mut rng, &kg, &Pattern::ALL, 24, NE as u32, NR as u32, NEG);
    if set.is_empty() {
        return;
    }
    let dag = set.train_dag();
    let (stats, _) = run_one(&rt, &dag, &st, EngineConfig::default(), None).unwrap();
    assert!(stats.gather_wait_secs >= 0.0);
    assert!(stats.worker_idle_secs >= 0.0);
    assert!(stats.overlap_secs <= stats.gather_secs + 1e-9);
    assert!(stats.overlap_secs <= stats.execute_secs + 1e-9);
    // every speculated round contributed one idle measurement, so with any
    // speculation at all the worker must have recorded parked time
    if stats.spec_hits + stats.spec_misses > 0 {
        assert!(stats.worker_idle_secs > 0.0, "worker idle time must be accounted");
    }
}

// ---------------------------------------------------------------------------
// Golden-schedule regression: the Max-Fillness schedule of a fixed workload
// (8×1p, embed capped at 2) is pinned to a checked-in snapshot so future
// scheduler edits diff visibly. Re-bless with NGDB_BLESS=1 after an
// *intentional* policy change.
// ---------------------------------------------------------------------------

const GOLDEN: &str = include_str!("golden/max_fillness_schedule.txt");

fn render_schedule(stats: &StepStats) -> String {
    stats
        .schedule
        .iter()
        .zip(&stats.fillness)
        .map(|((op, n), rho)| format!("{} x{} rho={:.3}\n", op.name(), n, rho))
        .collect()
}

#[test]
fn golden_max_fillness_schedule() {
    let mut rt = MockRuntime::new();
    rt.set_b_max_for("embed", 2);
    let st = mock_state(&rt);
    let set = QuerySet(
        (0..8)
            .map(|i| queries::QuerySpec {
                pattern: Pattern::P1,
                tree: QueryTree::instantiate(Pattern::P1, &[i % NE as u32], &[i % NR as u32])
                    .unwrap(),
                answer: 3,
                negatives: vec![0, 1],
            })
            .collect(),
    );
    let dag = set.train_dag();
    let pipe = run_one(&rt, &dag, &st, EngineConfig::default(), None).unwrap();
    let sync =
        run_one(&rt, &dag, &st, EngineConfig { pipeline: false, ..Default::default() }, None)
            .unwrap();
    assert_equivalent(&pipe, &sync).unwrap();

    let rendered = render_schedule(&pipe.0);
    if std::env::var("NGDB_BLESS").as_deref() == Ok("1") {
        let path =
            format!("{}/tests/golden/max_fillness_schedule.txt", env!("CARGO_MANIFEST_DIR"));
        std::fs::write(&path, &rendered).unwrap();
        eprintln!("blessed golden schedule -> {path}");
        return;
    }
    assert_eq!(
        rendered, GOLDEN,
        "Max-Fillness schedule changed; if intentional, re-bless with NGDB_BLESS=1"
    );
}
