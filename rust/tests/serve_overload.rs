//! Serving-tier overload suite: typed shedding with exact accounting, the
//! priority lane's starve-last contract, per-client fairness, bitwise
//! answer parity between loaded/unloaded and fixed/adaptive configurations,
//! and the Prometheus exposition round-trip. Run serially in CI
//! (`NGDB_STRESS` job) so thread timing actually exercises the intake.

use std::collections::HashMap;
use std::sync::Arc;
use std::time::Duration;

use ngdb_zoo::model::{ModelSnapshot, ModelState, SnapshotCell};
use ngdb_zoo::query::{Pattern, QueryTree};
use ngdb_zoo::runtime::{MockRuntime, Runtime};
use ngdb_zoo::serve::{
    BatchPolicy, Lane, QueryAnswer, QueryRequest, QueryService, ServeConfig, ServeError,
    ShedPolicy,
};

fn slow_rt(delay_ms: u64) -> Arc<MockRuntime> {
    Arc::new(MockRuntime::new().with_exec_delay(Duration::from_millis(delay_ms)))
}

fn snapshot(rt: &MockRuntime) -> Arc<SnapshotCell> {
    let state = ModelState::init(rt.manifest(), "mock", 24, 6, None, 11).unwrap();
    Arc::new(SnapshotCell::new(ModelSnapshot::capture(&state)))
}

fn req(i: u32) -> QueryRequest {
    QueryRequest {
        tree: QueryTree::instantiate(Pattern::P1, &[i % 24], &[i % 6]).unwrap(),
        filter: vec![],
        top_k: 4,
    }
}

/// A tiny (max_batch = 1, single worker) service whose every batch takes
/// real wall time, so the intake queue genuinely fills.
fn tiny_slow_cfg(queue_cap: usize, high_reserve: usize) -> ServeConfig {
    ServeConfig {
        workers: 1,
        max_batch: 1,
        max_wait: Duration::from_millis(1),
        queue_cap,
        high_reserve,
        shed: ShedPolicy::RejectNewest,
        ..Default::default()
    }
}

#[test]
fn shed_answers_are_typed_and_accounting_is_exact() {
    let rt = slow_rt(20);
    let service = QueryService::start(Arc::clone(&rt) as _, snapshot(&rt), tiny_slow_cfg(4, 0));
    let client = service.client();

    const N: usize = 40;
    let pending: Vec<_> = (0..N as u32).map(|i| client.submit(req(i)).unwrap()).collect();
    let (mut answered, mut shed) = (0usize, 0usize);
    for p in pending {
        match p.wait() {
            Ok(a) => {
                assert_eq!(a.top.len(), 4);
                answered += 1;
            }
            Err(ServeError::Overloaded { lane, queue_depth, queue_cap }) => {
                assert_eq!(lane, Lane::Normal);
                assert_eq!(queue_cap, 4, "the error reports the configured cap");
                assert!(queue_depth >= 4, "shed below the cap: depth {queue_depth}");
                shed += 1;
            }
            Err(e) => panic!("unexpected serve error: {e}"),
        }
    }
    assert_eq!(answered + shed, N, "every submission resolves — no silent drops");
    assert!(shed > 0, "a 4-deep queue at 40 instant submissions must shed");
    assert!(answered >= 4, "the queue's worth of requests must still be answered");

    // the registry agrees with the client's own accounting
    let m = service.metrics();
    assert_eq!(m.submitted(Lane::Normal).get(), N as u64);
    assert_eq!(m.accepted(Lane::Normal).get(), answered as u64);
    assert_eq!(m.shed(Lane::Normal).get(), shed as u64);
    assert_eq!(m.answered.get(), answered as u64);
    assert_eq!(m.latency.count(), answered as u64);
    drop(client);
    service.shutdown();
}

#[test]
fn high_lane_keeps_headroom_and_starves_last() {
    // queue_cap 4, high_reserve 2 → the normal lane may queue 2; the high
    // lane may fill all 4 slots. One slow worker (max_batch 1) so the
    // pipeline holds exactly: 1 executing + 1 queued window + 1 in the
    // batcher's hand, everything else waits in the intake.
    let rt = slow_rt(30);
    let service = QueryService::start(Arc::clone(&rt) as _, snapshot(&rt), tiny_slow_cfg(4, 2));
    let client = service.client();

    // a..c are absorbed by the pipeline (worker, batch channel, batcher)
    let absorbed: Vec<_> = (0..3u32)
        .map(|i| {
            let p = client.submit(req(i)).unwrap();
            std::thread::sleep(Duration::from_millis(20));
            p
        })
        .collect();
    // d, e fill the normal lane's 2 slots; f must shed
    let d = client.submit(req(3)).unwrap();
    let e = client.submit(req(4)).unwrap();
    let f = client.submit(req(5)).unwrap();
    // g, h ride the high lane into the reserved headroom; i finds the
    // queue truly full and sheds even at high priority
    let g = client.submit_priority(req(6)).unwrap();
    let h = client.submit_priority(req(7)).unwrap();
    let i = client.submit_priority(req(8)).unwrap();

    assert!(
        matches!(f.wait(), Err(ServeError::Overloaded { lane: Lane::Normal, .. })),
        "normal lane must shed at its reduced cap"
    );
    assert!(
        matches!(i.wait(), Err(ServeError::Overloaded { lane: Lane::High, .. })),
        "even the high lane sheds once the whole queue is full"
    );
    for p in absorbed {
        p.wait().unwrap();
    }
    let (d, e) = (d.wait().unwrap(), e.wait().unwrap());
    let (g, h) = (g.wait().unwrap(), h.wait().unwrap());
    // the high lane drains first: g/h entered the queue AFTER d/e but were
    // answered before them, so they waited strictly less
    for (hi, lo) in [(&g, &d), (&g, &e), (&h, &d), (&h, &e)] {
        assert!(
            hi.latency < lo.latency,
            "high-lane request waited {:?}, normal-lane only {:?}",
            hi.latency,
            lo.latency
        );
    }
    let m = service.metrics();
    assert_eq!(m.shed(Lane::Normal).get(), 1);
    assert_eq!(m.shed(Lane::High).get(), 1);
    drop(client);
    service.shutdown();
}

#[test]
fn fairness_sheds_the_flooding_client_not_the_light_one() {
    // normal_cap 16, two user clients → each is entitled to 8 queued
    // requests once the queue is half full. A floods 40; B's polite 4
    // must ALL be admitted while A sheds.
    let rt = slow_rt(20);
    let service =
        QueryService::start(Arc::clone(&rt) as _, snapshot(&rt), tiny_slow_cfg(16, 0));
    let flooder = service.client();
    let polite = service.client();

    let flood: Vec<_> = (0..40u32).map(|i| flooder.submit(req(i)).unwrap()).collect();
    let trickle: Vec<_> = (0..4u32).map(|i| polite.submit(req(100 + i)).unwrap()).collect();

    let mut flood_shed = 0usize;
    for p in flood {
        match p.wait() {
            Ok(_) => {}
            Err(ServeError::Overloaded { .. }) => flood_shed += 1,
            Err(e) => panic!("unexpected serve error: {e}"),
        }
    }
    assert!(flood_shed > 0, "40 instant submissions into 16 slots must shed");
    for p in trickle {
        p.wait().unwrap_or_else(|e| {
            panic!("the light client was shed while under its fair share: {e}")
        });
    }
    drop((flooder, polite));
    service.shutdown();
}

fn serve_all(
    rt: Arc<MockRuntime>,
    cfg: ServeConfig,
    reqs: &[QueryRequest],
) -> Vec<QueryAnswer> {
    let service = QueryService::start(rt.clone() as _, snapshot(&rt), cfg);
    let client = service.client();
    let pending: Vec<_> = reqs.iter().map(|r| client.submit(r.clone()).unwrap()).collect();
    let answers = pending.into_iter().map(|p| p.wait().unwrap()).collect();
    drop(client);
    service.shutdown();
    answers
}

#[test]
fn accepted_answers_under_overload_match_the_unloaded_path_bitwise() {
    // overloaded, shedding service: some requests shed, the rest answer
    let reqs: Vec<QueryRequest> = (0..40u32).map(req).collect();
    let rt = slow_rt(15);
    let service =
        QueryService::start(Arc::clone(&rt) as _, snapshot(&rt), tiny_slow_cfg(6, 0));
    let client = service.client();
    let pending: Vec<_> = reqs.iter().map(|r| client.submit(r.clone()).unwrap()).collect();
    let loaded: Vec<Option<QueryAnswer>> =
        pending.into_iter().map(|p| p.wait().ok()).collect();
    drop(client);
    service.shutdown();
    assert!(loaded.iter().any(|a| a.is_none()), "the overload never engaged");
    assert!(loaded.iter().any(|a| a.is_some()));

    // same requests, same weights, no load, no shedding
    let calm = serve_all(
        Arc::new(MockRuntime::new()),
        ServeConfig { queue_cap: 128, ..Default::default() },
        &reqs,
    );
    for (got, want) in loaded.iter().zip(&calm) {
        let Some(got) = got else { continue }; // shed — no answer to compare
        assert_eq!(got.top.len(), want.top.len());
        for ((ea, sa), (eb, sb)) in got.top.iter().zip(&want.top) {
            assert_eq!(ea, eb, "overload changed an accepted answer");
            assert_eq!(sa.to_bits(), sb.to_bits(), "scores must stay bit-identical");
        }
    }
}

#[test]
fn fixed_and_adaptive_windows_answer_bitwise_identically() {
    let reqs: Vec<QueryRequest> = (0..32u32).map(req).collect();
    let fixed = serve_all(
        Arc::new(MockRuntime::new()),
        ServeConfig { queue_cap: 128, batch: BatchPolicy::Fixed, ..Default::default() },
        &reqs,
    );
    let adaptive = serve_all(
        Arc::new(MockRuntime::new()),
        ServeConfig {
            queue_cap: 128,
            batch: BatchPolicy::Adaptive {
                p99_target: Duration::from_millis(5),
                min_wait: Duration::from_micros(100),
            },
            ..Default::default()
        },
        &reqs,
    );
    for (a, b) in fixed.iter().zip(&adaptive) {
        assert_eq!(a.top.len(), b.top.len());
        for ((ea, sa), (eb, sb)) in a.top.iter().zip(&b.top) {
            assert_eq!(ea, eb, "the window policy changed an answer");
            assert_eq!(sa.to_bits(), sb.to_bits());
        }
    }
}

/// Minimal exposition-format reader: `name{labels} value` per sample line,
/// `# TYPE name kind` headers. Enough structure to verify the renderer
/// round-trips (the python CI job runs the full grammar validator).
fn parse_prometheus(text: &str) -> (HashMap<String, f64>, HashMap<String, String>) {
    let mut samples = HashMap::new();
    let mut types = HashMap::new();
    for line in text.lines() {
        if let Some(rest) = line.strip_prefix("# TYPE ") {
            let mut it = rest.split_whitespace();
            let name = it.next().expect("TYPE line has a name");
            let kind = it.next().expect("TYPE line has a kind");
            types.insert(name.to_string(), kind.to_string());
        } else if !line.starts_with('#') && !line.is_empty() {
            let (series, value) = line.rsplit_once(' ').expect("sample line has a value");
            let value: f64 = value.parse().unwrap_or_else(|_| {
                panic!("unparseable sample value in {line:?}")
            });
            assert!(
                samples.insert(series.to_string(), value).is_none(),
                "duplicate series {series}"
            );
        }
    }
    (samples, types)
}

#[test]
fn prometheus_rendering_round_trips_and_adds_up() {
    let rt = slow_rt(15);
    let service =
        QueryService::start(Arc::clone(&rt) as _, snapshot(&rt), tiny_slow_cfg(4, 0));
    let client = service.client();
    let pending: Vec<_> = (0..24u32).map(|i| client.submit(req(i)).unwrap()).collect();
    for p in pending {
        let _ = p.wait();
    }

    let text = service.metrics().render_prometheus();
    let (samples, types) = parse_prometheus(&text);

    // counters are declared and consistent with each other
    assert_eq!(types["ngdb_serve_submitted_total"], "counter");
    assert_eq!(types["ngdb_serve_latency_seconds"], "histogram");
    let sub = samples["ngdb_serve_submitted_total{lane=\"normal\"}"];
    let acc = samples["ngdb_serve_accepted_total{lane=\"normal\"}"];
    let shed = samples["ngdb_serve_shed_total{lane=\"normal\"}"];
    assert_eq!(sub, 24.0);
    assert_eq!(acc + shed, sub, "accepted + shed must cover every submission");
    assert_eq!(samples["ngdb_serve_answered_total"], acc, "all accepted were answered");

    // histogram buckets are cumulative, monotone, and +Inf == _count
    for h in ["ngdb_serve_latency_seconds", "ngdb_serve_batch_fill"] {
        let mut buckets: Vec<(&String, f64)> = samples
            .iter()
            .filter(|(k, _)| k.starts_with(&format!("{h}_bucket")))
            .map(|(k, &v)| (k, v))
            .collect();
        assert!(!buckets.is_empty(), "{h} rendered no buckets");
        // render order == bound order; recover it by cumulative value,
        // then re-verify monotonicity pairwise against parsed bounds
        buckets.sort_by(|a, b| a.1.total_cmp(&b.1));
        let inf = samples[&format!("{h}_bucket{{le=\"+Inf\"}}")];
        assert_eq!(inf, samples[&format!("{h}_count")], "+Inf bucket == count");
        assert!(buckets.iter().all(|(_, v)| *v <= inf));
        assert!(samples.contains_key(&format!("{h}_sum")));
    }
    assert_eq!(samples["ngdb_serve_latency_seconds_count"], acc);
    drop(client);
    service.shutdown();
}

#[test]
fn metrics_endpoint_serves_the_exposition_over_tcp() {
    use std::io::{Read, Write};
    let rt = Arc::new(MockRuntime::new());
    let service = QueryService::start(
        Arc::clone(&rt) as _,
        snapshot(&rt),
        ServeConfig { metrics_addr: Some("127.0.0.1:0".into()), ..Default::default() },
    );
    let client = service.client();
    client.query(req(1)).unwrap();
    let addr = service.metrics_addr().expect("the endpoint must bind on an ephemeral port");
    let mut stream = std::net::TcpStream::connect(addr).unwrap();
    stream.write_all(b"GET /metrics HTTP/1.1\r\nHost: t\r\n\r\n").unwrap();
    let mut body = String::new();
    stream.read_to_string(&mut body).unwrap();
    assert!(body.starts_with("HTTP/1.1 200 OK"), "bad status line: {body}");
    assert!(body.contains("text/plain; version=0.0.4"));
    assert!(body.contains("ngdb_serve_answered_total 1"));
    drop(client);
    service.shutdown();
}
