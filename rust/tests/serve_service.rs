//! QueryService integration: determinism across batching windows, and the
//! concurrent-clients smoke — N client threads submitting while a real
//! trainer steps and publishes snapshots in parallel. Run serially in CI
//! (`NGDB_STRESS` job) so thread timing actually exercises the windows.

use std::sync::Arc;
use std::time::Duration;

use ngdb_zoo::config::{Batching, ExperimentConfig, Pipelining};
use ngdb_zoo::kg::{KgSpec, KgStore};
use ngdb_zoo::model::{ModelSnapshot, ModelState, SnapshotCell};
use ngdb_zoo::query::{Pattern, QueryTree};
use ngdb_zoo::runtime::{MockRuntime, Runtime};
use ngdb_zoo::sampler::ground;
use ngdb_zoo::serve::{QueryAnswer, QueryRequest, QueryService, ServeConfig};
use ngdb_zoo::train::Trainer;
use ngdb_zoo::util::rng::Rng;

fn small_state(rt: &MockRuntime) -> ModelState {
    ModelState::init(rt.manifest(), "mock", 24, 6, None, 11).unwrap()
}

/// Deterministic request set over the small 24-entity state.
fn requests(n: usize) -> Vec<QueryRequest> {
    (0..n as u32)
        .map(|i| {
            let tree = match i % 3 {
                0 => QueryTree::instantiate(Pattern::P1, &[i % 24], &[i % 6]).unwrap(),
                1 => QueryTree::instantiate(
                    Pattern::P2,
                    &[(i + 7) % 24],
                    &[i % 6, (i + 1) % 6],
                )
                .unwrap(),
                _ => QueryTree::instantiate(
                    Pattern::I2,
                    &[i % 24, (i + 5) % 24],
                    &[i % 6, (i + 2) % 6],
                )
                .unwrap(),
            };
            QueryRequest { tree, filter: vec![i % 24], top_k: 5 }
        })
        .collect()
}

fn serve_all(cfg: ServeConfig, reqs: &[QueryRequest]) -> Vec<QueryAnswer> {
    let rt = Arc::new(MockRuntime::new());
    let state = small_state(&rt);
    let cell = Arc::new(SnapshotCell::new(ModelSnapshot::capture(&state)));
    let service = QueryService::start(rt, cell, cfg);
    let client = service.client();
    let pending: Vec<_> =
        reqs.iter().map(|r| client.submit(r.clone()).unwrap()).collect();
    let answers: Vec<QueryAnswer> =
        pending.into_iter().map(|p| p.wait().unwrap()).collect();
    drop(client);
    service.shutdown();
    answers
}

#[test]
fn same_requests_same_snapshot_same_top_k_across_windows_and_workers() {
    // Scoring is row-local, so the answers must be INDEPENDENT of how
    // requests were micro-batched and how many workers raced — the serving
    // analogue of "batched equals singleton numerics".
    let reqs = requests(24);
    let singleton = serve_all(
        ServeConfig { workers: 1, max_batch: 1, ..Default::default() },
        &reqs,
    );
    let fused = serve_all(
        ServeConfig {
            workers: 4,
            max_batch: 16,
            max_wait: Duration::from_millis(10),
            ..Default::default()
        },
        &reqs,
    );
    let fused_again = serve_all(
        ServeConfig {
            workers: 4,
            max_batch: 16,
            max_wait: Duration::from_millis(10),
            ..Default::default()
        },
        &reqs,
    );
    for ((a, b), c) in singleton.iter().zip(&fused).zip(&fused_again) {
        assert_eq!(a.top.len(), b.top.len());
        for ((ea, sa), (eb, sb)) in a.top.iter().zip(&b.top) {
            assert_eq!(ea, eb, "answers depend on the batching window");
            assert_eq!(sa.to_bits(), sb.to_bits(), "scores must be bit-identical");
        }
        assert_eq!(b.top, c.top, "same requests + same snapshot must replay");
    }
    // fusion actually happened in the fused run
    assert!(
        fused.iter().any(|a| a.batch_size > 1),
        "no fused batch formed under a 16-wide window"
    );
}

#[test]
fn filtered_answers_respect_each_requests_own_filter() {
    let reqs = requests(12);
    let answers = serve_all(ServeConfig::default(), &reqs);
    for (req, ans) in reqs.iter().zip(&answers) {
        for (e, _) in &ans.top {
            assert!(!req.filter.contains(e), "filtered id {e} appeared");
        }
        assert_eq!(ans.top.len(), 5);
        assert!(ans.top.windows(2).all(|w| w[0].1 >= w[1].1), "score-descending");
        assert!(ans.top.iter().all(|(_, s)| s.is_finite()));
    }
}

/// The headline smoke: ≥4 client threads hammer the service while a real
/// `Trainer` runs in parallel, publishing a snapshot after every optimizer
/// step. Every answer must come from a *published* snapshot (step within
/// the published range — never a torn/partial state, which cannot exist
/// by construction since workers pin one `Arc` per batch), and serving
/// must keep answering across the swaps.
#[test]
fn concurrent_clients_while_a_trainer_publishes_snapshots() {
    const STEPS: usize = 6;
    const CLIENTS: usize = 4;
    const PER_CLIENT: usize = 30;

    // the serve backend CLAIMS no concurrent execute: with 2 workers
    // ranking in parallel, every submission must route through the gated
    // path — the mock's breach detector (asserted at the end) pins the
    // runtime concurrency contract on the serve plane
    let rt_serve = {
        let mut m = MockRuntime::new();
        m.set_concurrent_execute_safe(false);
        Arc::new(m)
    };
    let rt_train = MockRuntime::new(); // same manifest, separate backend
    let kg: Arc<KgStore> = Arc::new(KgSpec::preset("toy", 0.1).unwrap().generate().unwrap());
    let mut state = ModelState::init(
        rt_train.manifest(),
        "mock",
        kg.n_entities,
        kg.n_relations,
        None,
        5,
    )
    .unwrap();
    let cell = Arc::new(SnapshotCell::new(ModelSnapshot::capture(&state)));

    let service = QueryService::start(
        Arc::clone(&rt_serve) as Arc<dyn Runtime>,
        Arc::clone(&cell),
        ServeConfig {
            workers: 2,
            max_batch: 8,
            max_wait: Duration::from_millis(1),
            ..Default::default()
        },
    );
    let client = service.client();

    let tcfg = ExperimentConfig {
        model: "mock".into(),
        steps: STEPS,
        batch_queries: 16,
        batching: Batching::OperatorLevel,
        pipelining: Pipelining::Sync,
        patterns: vec![Pattern::P1, Pattern::P2, Pattern::I2],
        ..Default::default()
    };

    let answers: Vec<QueryAnswer> = std::thread::scope(|s| {
        let trainer_cell = Arc::clone(&cell);
        let trainer_kg = Arc::clone(&kg);
        let state_ref = &mut state;
        let trainer = s.spawn(move || {
            Trainer::new(&rt_train, trainer_kg, tcfg)
                .with_snapshots(trainer_cell)
                .train(state_ref)
                .unwrap();
        });

        let clients: Vec<_> = (0..CLIENTS)
            .map(|c| {
                let client = client.clone();
                let kg = Arc::clone(&kg);
                s.spawn(move || {
                    let mut rng = Rng::new(100 + c as u64);
                    let mut got = Vec::with_capacity(PER_CLIENT);
                    let mut guard = 0usize;
                    while got.len() < PER_CLIENT && guard < PER_CLIENT * 40 {
                        guard += 1;
                        let p = *rng.choice(&[Pattern::P1, Pattern::P2, Pattern::I2]);
                        let Some(g) = ground(&kg, &mut rng, p) else { continue };
                        let req = QueryRequest {
                            tree: g.tree,
                            filter: vec![g.answer],
                            top_k: 4,
                        };
                        got.push(client.query(req).unwrap());
                    }
                    got
                })
            })
            .collect();
        let answers: Vec<QueryAnswer> = clients
            .into_iter()
            .flat_map(|h| h.join().expect("client thread panicked"))
            .collect();
        trainer.join().expect("trainer thread panicked");
        answers
    });

    assert!(answers.len() >= CLIENTS * PER_CLIENT / 2, "clients were starved");
    for a in &answers {
        assert!(
            a.snapshot_step as usize <= STEPS,
            "answer from an unpublished snapshot step {}",
            a.snapshot_step
        );
        assert_eq!(a.top.len(), 4);
        assert!(a.top.iter().all(|(_, s)| s.is_finite()));
    }
    assert_eq!(cell.published(), 1 + STEPS as u64);

    // after the trainer finished, serving must observe its final publish
    let final_tree = QueryTree::instantiate(Pattern::P1, &[0], &[0]).unwrap();
    let late = client
        .query(QueryRequest { tree: final_tree, filter: vec![], top_k: 3 })
        .unwrap();
    assert_eq!(late.snapshot_step as usize, STEPS, "final snapshot must be served");

    assert_eq!(
        rt_serve
            .contract_violations
            .load(std::sync::atomic::Ordering::SeqCst),
        0,
        "concurrent serve workers must never bypass the submission lock"
    );

    drop(client);
    service.shutdown();
}
