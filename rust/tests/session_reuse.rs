//! Session-reuse equivalence suite: N sequential DAGs through one
//! [`EngineSession`] must produce bit-identical losses, gradients and
//! schedules to N fresh per-run engines — with and without semantic
//! fusion — while spawning exactly **one** gather worker for the whole
//! session (the per-run engines spawn one per DAG). The spawn accounting
//! reads the process-global counter `exec::worker_spawns_total()`, so
//! every test in this binary serializes on one lock to keep the deltas
//! attributable.

use std::sync::{Mutex, MutexGuard};

use ngdb_zoo::exec::{
    worker_spawns_total, Engine, EngineConfig, EngineSession, Grads, StepStats,
};
use ngdb_zoo::model::ModelState;
use ngdb_zoo::query::{Pattern, QueryDag, QueryTree};
use ngdb_zoo::runtime::{MockRuntime, Runtime};
use ngdb_zoo::semantic::mock::{EncoderSource, TableSource};
use ngdb_zoo::semantic::SemanticSource;
use ngdb_zoo::util::proptest::queries;
use ngdb_zoo::util::rng::Rng;

const NE: usize = 12; // mock entity rows
const NR: usize = 6; // mock relation rows
const NEG: usize = 2; // mock n_neg

/// Every test here measures deltas of the process-global worker-spawn
/// counter, so tests must not create sessions concurrently.
static SERIAL: Mutex<()> = Mutex::new(());

fn serial() -> MutexGuard<'static, ()> {
    SERIAL.lock().unwrap_or_else(|e| e.into_inner())
}

fn mock_state(rt: &MockRuntime) -> ModelState {
    ModelState::init(rt.manifest(), "mock", NE, NR, None, 3).unwrap()
}

/// A varied workload: several DAGs of mixed patterns, deterministic.
fn workload(n_dags: usize, queries_per_dag: usize) -> Vec<QueryDag> {
    let kg = queries::toy_kg();
    let mut rng = Rng::new(0xD06);
    (0..n_dags)
        .map(|_| {
            loop {
                let set = queries::random_set(
                    &mut rng,
                    &kg,
                    &Pattern::ALL,
                    queries_per_dag,
                    NE as u32,
                    NR as u32,
                    NEG,
                );
                if !set.is_empty() {
                    return set.train_dag();
                }
            }
        })
        .collect()
}

fn assert_bit_identical(
    (s_a, g_a): &(StepStats, Grads),
    (s_b, g_b): &(StepStats, Grads),
    ctx: &str,
) {
    assert_eq!(s_a.schedule, s_b.schedule, "{ctx}: schedules diverge");
    assert_eq!(s_a.fillness, s_b.fillness, "{ctx}: fillness traces diverge");
    assert_eq!(
        s_a.loss.to_bits(),
        s_b.loss.to_bits(),
        "{ctx}: loss not bit-identical ({} vs {})",
        s_a.loss,
        s_b.loss
    );
    for (map_a, map_b, tag) in
        [(&g_a.ent, &g_b.ent, "ent"), (&g_a.rel, &g_b.rel, "rel")]
    {
        assert_eq!(map_a.len(), map_b.len(), "{ctx}: {tag} key counts");
        for (k, v) in map_a {
            let w = &map_b[k];
            for (i, (x, y)) in v.iter().zip(w).enumerate() {
                assert_eq!(x.to_bits(), y.to_bits(), "{ctx}: {tag}[{k}][{i}]: {x} vs {y}");
            }
        }
    }
    assert_eq!(g_a.dense.len(), g_b.dense.len(), "{ctx}: dense key counts");
    for (k, v) in &g_a.dense {
        let w = &g_b.dense[k];
        for (i, (x, y)) in v.iter().zip(w).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "{ctx}: dense[{k}][{i}]: {x} vs {y}");
        }
    }
}

/// Run the workload once through a single reused session and once through
/// fresh per-run engines; assert bitwise equality per DAG and the spawn
/// accounting: 1 spawn for the session (at creation, none per run), one
/// per DAG for the per-run path.
fn check_session_vs_per_run(rt: &MockRuntime, semantic: Option<&dyn SemanticSource>) {
    let st = mock_state(rt);
    let dags = workload(6, 12);

    let before_session = worker_spawns_total();
    let mut session = match semantic {
        Some(s) => EngineSession::with_semantic(rt, EngineConfig::default(), s),
        None => EngineSession::new(rt, EngineConfig::default()),
    };
    assert_eq!(worker_spawns_total() - before_session, 1, "one spawn at creation");
    let after_create = worker_spawns_total();

    let session_runs: Vec<(StepStats, Grads)> = dags
        .iter()
        .map(|dag| {
            let mut grads = Grads::default();
            let stats = session.run(dag, &st, &mut grads).unwrap();
            (stats, grads)
        })
        .collect();
    assert_eq!(
        worker_spawns_total(),
        after_create,
        "no scoped/owned thread may be spawned inside EngineSession::run"
    );
    assert_eq!(session.worker_spawns(), 1);

    let before_per_run = worker_spawns_total();
    let per_runs: Vec<(StepStats, Grads)> = dags
        .iter()
        .map(|dag| {
            let engine = match semantic {
                Some(s) => Engine::with_semantic(rt, EngineConfig::default(), s),
                None => Engine::new(rt, EngineConfig::default()),
            };
            let mut grads = Grads::default();
            let stats = engine.run(dag, &st, &mut grads).unwrap();
            (stats, grads)
        })
        .collect();
    assert_eq!(
        worker_spawns_total() - before_per_run,
        dags.len() as u64,
        "per-run engines pay one spawn per DAG — the cost the session amortizes"
    );

    for (i, (sess, per)) in session_runs.iter().zip(&per_runs).enumerate() {
        assert_bit_identical(sess, per, &format!("dag {i}"));
    }
}

#[test]
fn session_reuse_matches_per_run_engines_bitwise() {
    let _guard = serial();
    let rt = MockRuntime::new();
    check_session_vs_per_run(&rt, None);
}

#[test]
fn session_reuse_matches_per_run_engines_under_table_fusion() {
    let _guard = serial();
    let rt = MockRuntime::new();
    let sem = TableSource::linear(NE, rt.manifest().dims.d);
    check_session_vs_per_run(&rt, Some(&sem));
}

#[test]
fn session_reuse_matches_per_run_engines_under_encoder_fusion() {
    // joint-style fusion: the session's gather worker executes encoder
    // artifacts through the gated path while rounds execute on the main
    // thread — reuse must stay bit-identical AND contract-clean
    let _guard = serial();
    let mut rt = MockRuntime::new();
    rt.set_concurrent_execute_safe(false);
    let sem = EncoderSource::new(&rt, NE);
    check_session_vs_per_run(&rt, Some(&sem));
    assert_eq!(
        rt.contract_violations.load(std::sync::atomic::Ordering::SeqCst),
        0,
        "session-reused encoder gathers must respect the submission lock"
    );
}

#[test]
fn per_op_caps_survive_session_reuse() {
    // b_max_by_op routing goes through the planning core; a reused session
    // must keep honoring it on every run
    let _guard = serial();
    let mut rt = MockRuntime::new();
    rt.set_b_max_for("embed", 2);
    check_session_vs_per_run(&rt, None);
}

#[test]
fn sync_sessions_spawn_no_workers_and_match_pipelined_sessions() {
    let _guard = serial();
    let rt = MockRuntime::new();
    let st = mock_state(&rt);
    let dags = workload(4, 10);

    let before = worker_spawns_total();
    let mut sync_session =
        EngineSession::new(&rt, EngineConfig { pipeline: false, ..Default::default() });
    assert_eq!(worker_spawns_total(), before, "sync sessions need no thread");
    assert_eq!(sync_session.worker_spawns(), 0);

    let mut pipe_session = EngineSession::new(&rt, EngineConfig::default());
    for (i, dag) in dags.iter().enumerate() {
        let mut g_sync = Grads::default();
        let s_sync = sync_session.run(dag, &st, &mut g_sync).unwrap();
        let mut g_pipe = Grads::default();
        let s_pipe = pipe_session.run(dag, &st, &mut g_pipe).unwrap();
        assert_bit_identical(
            &(s_pipe, g_pipe),
            &(s_sync, g_sync),
            &format!("sync-vs-pipelined dag {i}"),
        );
    }
}

#[test]
fn failed_runs_do_not_poison_the_session() {
    // a DAG whose artifact is missing errors cleanly; the same session —
    // same worker — then runs a valid DAG bit-identically to a fresh engine
    let _guard = serial();
    let rt = MockRuntime::new();
    let st = mock_state(&rt);
    let mut session = EngineSession::new(&rt, EngineConfig::default());
    let after_create = worker_spawns_total();

    let bad_tree = QueryTree::Intersect(vec![
        QueryTree::Anchor(0),
        QueryTree::Anchor(1),
        QueryTree::Anchor(2),
        QueryTree::Anchor(3),
    ]);
    let mut bad = QueryDag::default();
    bad.add_query(&bad_tree, 5, vec![0, 1], "custom", true).unwrap();
    bad.add_gradient_nodes();
    let mut grads = Grads::default();
    let err = session.run(&bad, &st, &mut grads).unwrap_err();
    assert!(format!("{err:#}").contains("intersect4"), "{err:#}");

    let dags = workload(1, 12);
    let dag = &dags[0];
    let mut g_sess = Grads::default();
    let s_sess = session.run(dag, &st, &mut g_sess).unwrap();
    let engine = Engine::new(&rt, EngineConfig::default());
    let mut g_run = Grads::default();
    let s_run = engine.run(dag, &st, &mut g_run).unwrap();
    assert_bit_identical(&(s_sess, g_sess), &(s_run, g_run), "post-error run");
    assert_eq!(worker_spawns_total() - after_create, 1, "only the fresh engine spawned");
}

#[test]
fn eval_outputs_survive_session_reuse() {
    // run_with_outputs through a reused session returns the same pinned
    // reprs on every run
    let _guard = serial();
    let rt = MockRuntime::new();
    let st = mock_state(&rt);
    let tree = QueryTree::instantiate(Pattern::P1, &[4], &[2]).unwrap();
    let mut dag = QueryDag::default();
    let root = dag.add_query_eval(&tree, true).unwrap();
    let want: Vec<f32> = st
        .entities
        .row(4)
        .iter()
        .zip(st.relations.row(2))
        .map(|(a, b)| a + b)
        .collect();
    let mut session = EngineSession::new(&rt, EngineConfig::default());
    for _ in 0..3 {
        let mut grads = Grads::default();
        let (_, outs) = session.run_with_outputs(&dag, &st, &mut grads, &[root]).unwrap();
        assert_eq!(outs[0], want);
    }
}
