//! Shard-parity property suite: the sharded embedding store and the
//! scatter-gather serve path must be *bitwise* indistinguishable from the
//! flat reference — for every shard count, every worker count, and for
//! delta-published snapshots vs. fresh full captures after real training.

use std::sync::Arc;
use std::time::Duration;

use ngdb_zoo::config::{Batching, ExperimentConfig, Pipelining};
use ngdb_zoo::kg::{KgSpec, KgStore};
use ngdb_zoo::model::{ModelSnapshot, ModelState, ShardLayout, SnapshotCell};
use ngdb_zoo::query::{Pattern, QueryTree};
use ngdb_zoo::runtime::{MockRuntime, Runtime};
use ngdb_zoo::serve::{QueryAnswer, QueryRequest, QueryService, ServeConfig};
use ngdb_zoo::train::Trainer;

const SHARD_SWEEP: [usize; 4] = [1, 2, 4, 7];

#[test]
fn routing_partitions_every_id_for_any_shard_count() {
    for n in SHARD_SWEEP {
        let layout = ShardLayout::new(n);
        for total in [0usize, 1, 3, 24, 100, 101] {
            let mut per_shard = vec![0usize; n];
            for id in 0..total as u32 {
                let (s, l) = (layout.shard_of(id), layout.local_of(id));
                assert_eq!(layout.global_of(s, l), id, "n={n} id={id} round trip");
                assert!(l < layout.shard_rows(total, s), "n={n} id={id} local bound");
                per_shard[s] += 1;
            }
            for (s, &count) in per_shard.iter().enumerate() {
                assert_eq!(count, layout.shard_rows(total, s), "n={n} total={total}");
            }
            // balanced to within one row: no hot shard under modulo routing
            if total >= n {
                let sizes: Vec<usize> = (0..n).map(|s| layout.shard_rows(total, s)).collect();
                let (min, max) = (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
                assert!(max - min <= 1, "n={n} total={total} skewed: {sizes:?}");
            }
        }
    }
}

#[test]
fn sharded_captures_are_bitwise_identical_to_the_live_state() {
    let rt = MockRuntime::new();
    let state = ModelState::init(rt.manifest(), "mock", 23, 5, None, 17).unwrap();
    for n in SHARD_SWEEP {
        let snap = ModelSnapshot::capture_sharded(&state, n);
        assert_eq!(snap.n_shards(), n);
        assert_eq!(snap.entities().to_flat(), state.entities.data, "n={n} entities");
        assert_eq!(snap.relations().to_flat(), state.relations.data, "n={n} relations");
        // routed single-row reads agree with the flat layout too
        for id in 0..state.entities.rows as u32 {
            assert_eq!(snap.entities().row(id), state.entities.row(id), "n={n} id={id}");
        }
    }
}

/// Real training drives the delta path: a `Trainer` publishing after every
/// optimizer step must produce snapshots bitwise identical to a fresh full
/// capture of the same state, while actually copying only touched pages.
#[test]
fn trained_delta_publishes_are_bitwise_identical_to_full_captures() {
    const STEPS: usize = 5;
    let rt = MockRuntime::new();
    let kg: Arc<KgStore> = Arc::new(KgSpec::preset("toy", 0.1).unwrap().generate().unwrap());
    let mut state =
        ModelState::init(rt.manifest(), "mock", kg.n_entities, kg.n_relations, None, 7).unwrap();
    let cell = Arc::new(SnapshotCell::new(ModelSnapshot::capture(&state)));
    let pinned = cell.load();
    let pinned_ents = pinned.entities().to_flat();

    let cfg = ExperimentConfig {
        model: "mock".into(),
        steps: STEPS,
        batch_queries: 16,
        batching: Batching::OperatorLevel,
        pipelining: Pipelining::Sync,
        patterns: vec![Pattern::P1, Pattern::P2],
        ..Default::default()
    };
    Trainer::new(&rt, kg, cfg)
        .with_snapshots(Arc::clone(&cell))
        .train(&mut state)
        .unwrap();

    // the first publish has no dirty baseline (fresh init) and goes full;
    // every later one must ride the COW delta path
    let totals = cell.publish_totals();
    assert!(totals.full_publishes <= 1, "re-anchoring failed: {totals:?}");
    assert_eq!(totals.delta_publishes, (STEPS as u64 - 1).max(0), "{totals:?}");

    // bitwise identity of the final delta-published snapshot vs. a fresh
    // full capture of the state it was published from
    let published = cell.load();
    assert_eq!(published.step(), STEPS as u64);
    let full = ModelSnapshot::capture_sharded(&state, published.n_shards());
    let (a, b) = (published.entities().to_flat(), full.entities().to_flat());
    assert_eq!(a.len(), b.len());
    for (i, (x, y)) in a.iter().zip(&b).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "entity weight {i} diverged");
    }
    let (a, b) = (published.relations().to_flat(), full.relations().to_flat());
    for (i, (x, y)) in a.iter().zip(&b).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "relation weight {i} diverged");
    }

    // COW isolation: the snapshot pinned before training still reads the
    // step-0 weights even though later publishes shared its pages
    assert_eq!(pinned.entities().to_flat(), pinned_ents);

    // and the deltas were actually cheap: total bytes copied across all
    // publishes stays below STEPS full captures (the economics the
    // snapshot_publish bench gates precisely)
    assert!(
        (totals.bytes_copied as usize) < STEPS * full.bytes(),
        "delta publishing copied as much as full captures: {totals:?}"
    );
}

fn answers_for(state: &ModelState, n_shards: usize, workers: usize) -> Vec<QueryAnswer> {
    let rt = Arc::new(MockRuntime::new());
    let cell = Arc::new(SnapshotCell::new(ModelSnapshot::capture_sharded(state, n_shards)));
    let service = QueryService::start(
        rt,
        cell,
        ServeConfig {
            workers,
            max_batch: 8,
            max_wait: Duration::from_millis(2),
            ..Default::default()
        },
    );
    let client = service.client();
    let reqs: Vec<QueryRequest> = (0..18u32)
        .map(|i| {
            let tree = match i % 3 {
                0 => QueryTree::instantiate(Pattern::P1, &[i % 24], &[i % 6]).unwrap(),
                1 => QueryTree::instantiate(Pattern::P2, &[(i + 7) % 24], &[i % 6, (i + 1) % 6])
                    .unwrap(),
                _ => QueryTree::instantiate(
                    Pattern::I2,
                    &[i % 24, (i + 5) % 24],
                    &[i % 6, (i + 2) % 6],
                )
                .unwrap(),
            };
            // sweep k across shard-boundary shapes, including "everything"
            QueryRequest { tree, filter: vec![i % 24, (i + 3) % 24], top_k: 1 + (i as usize % 23) }
        })
        .collect();
    let pending: Vec<_> = reqs.iter().map(|r| client.submit(r.clone()).unwrap()).collect();
    let answers = pending.into_iter().map(|p| p.wait().unwrap()).collect();
    drop(client);
    service.shutdown();
    answers
}

/// The headline guarantee: served answers are a pure function of
/// (snapshot weights, request) — shard count and worker count must be
/// invisible, down to the score bits.
#[test]
fn served_answers_are_bitwise_identical_across_shard_and_worker_counts() {
    let rt = MockRuntime::new();
    let state = ModelState::init(rt.manifest(), "mock", 24, 6, None, 11).unwrap();
    let reference = answers_for(&state, 1, 1); // single shard, single worker
    assert!(reference.iter().any(|a| a.top.len() > 4), "degenerate reference");
    for n_shards in SHARD_SWEEP {
        for workers in [1usize, 2] {
            let got = answers_for(&state, n_shards, workers);
            assert_eq!(got.len(), reference.len());
            for (i, (g, r)) in got.iter().zip(&reference).enumerate() {
                assert_eq!(
                    g.top.len(),
                    r.top.len(),
                    "req {i}: answer width drifted at shards={n_shards} workers={workers}"
                );
                for ((ge, gs), (re, rs)) in g.top.iter().zip(&r.top) {
                    assert_eq!(ge, re, "req {i} shards={n_shards} workers={workers}");
                    assert_eq!(
                        gs.to_bits(),
                        rs.to_bits(),
                        "req {i} score bits drifted at shards={n_shards} workers={workers}"
                    );
                }
            }
        }
    }
}
