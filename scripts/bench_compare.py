#!/usr/bin/env python3
"""Direction-aware bench-JSON regression gate (stdlib only).

Compares a candidate bench artifact (``BENCH_*.json``, produced by the
Rust bench harnesses) against a committed baseline and exits non-zero on
regression. Every **numeric leaf of the baseline** is a gate; the
direction is inferred from the key path:

* higher-is-better: throughput-ish names (``*_per_s``, ``speedup``,
  ``qps``, ``hits``, ...) — the candidate must not fall more than
  ``--threshold-pct`` below the baseline;
* lower-is-better: cost-ish names (``alloc``, ``bytes``, ``miss``,
  ``spawn``, ``latency``, ``p95``, ...) — the candidate must not rise
  more than ``--threshold-pct`` above it. A **zero** baseline here is an
  exact gate: the candidate must stay at zero (you cannot take a
  percentage of nothing, and "zero steady-state spawns/misses" is a
  contract, not a measurement);
* anything under a ``config`` key, booleans, strings, and keys matching
  neither pattern list are informational only.

A baseline key missing from the candidate fails: silently dropping a
gated metric is how regressions hide. Extra candidate keys are fine —
benches may grow fields before the baseline is re-blessed.

The baseline should only pin machine-robust fields (counts, ratios,
budget-bounded averages) — absolute wall-clock throughput varies too
much across CI runners to gate at any sane threshold.
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

# checked in order: the first list that matches wins, HIGHER first, so
# "speedup_rounds_per_sec" (which also contains "per_s") gates upward
HIGHER_PATTERNS = ("per_s", "per_sec", "speedup", "qps", "hits", "elems", "gb_per_s")
LOWER_PATTERNS = (
    "alloc",
    "bytes",
    "miss",
    "spawn",
    "latency",
    "shed",
    "publish",
    "copied",
    "p50",
    "p95",
    "p99",
    "secs",
    "_us",
    "_ms",
    "fallback",
    "failure",
    "resident",
    "mapped",
    "rss",
)


def flatten(node, prefix=""):
    """Yield (dotted-path, value) for every numeric leaf. Booleans are not
    numbers here; strings and nulls are skipped."""
    if isinstance(node, dict):
        for key, value in node.items():
            yield from flatten(value, f"{prefix}{key}.")
    elif isinstance(node, list):
        for i, value in enumerate(node):
            yield from flatten(value, f"{prefix}{i}.")
    elif isinstance(node, bool) or node is None or isinstance(node, str):
        return
    elif isinstance(node, (int, float)):
        yield prefix.rstrip("."), float(node)


def direction(path: str):
    """'higher', 'lower', or None (ungated) for a dotted key path."""
    lowered = path.lower()
    if any(seg == "config" for seg in lowered.split(".")):
        return None
    if any(p in lowered for p in HIGHER_PATTERNS):
        return "higher"
    if any(p in lowered for p in LOWER_PATTERNS):
        return "lower"
    return None


def compare(baseline: dict, candidate: dict, threshold_pct: float):
    """Return (rows, failures): one row per baseline leaf, and the subset
    that regressed (or went missing)."""
    cand = dict(flatten(candidate))
    rows, failures = [], []
    for path, base_val in flatten(baseline):
        dirn = direction(path)
        if path not in cand:
            rows.append((path, base_val, None, dirn or "-", "MISSING"))
            if dirn is not None:
                failures.append(f"{path}: gated metric missing from candidate")
            continue
        cand_val = cand[path]
        status = "info"
        if dirn == "higher":
            floor = base_val * (1.0 - threshold_pct / 100.0)
            status = "ok" if cand_val >= floor else "FAIL"
            if status == "FAIL":
                failures.append(
                    f"{path}: {cand_val:g} fell below {floor:g} "
                    f"(baseline {base_val:g} - {threshold_pct:g}%)"
                )
        elif dirn == "lower":
            if base_val == 0.0:
                status = "ok" if cand_val <= 0.0 else "FAIL"
                if status == "FAIL":
                    failures.append(f"{path}: {cand_val:g} > 0 (exact zero contract)")
            else:
                ceil = base_val * (1.0 + threshold_pct / 100.0)
                status = "ok" if cand_val <= ceil else "FAIL"
                if status == "FAIL":
                    failures.append(
                        f"{path}: {cand_val:g} rose above {ceil:g} "
                        f"(baseline {base_val:g} + {threshold_pct:g}%)"
                    )
        rows.append((path, base_val, cand_val, dirn or "-", status))
    return rows, failures


def print_table(rows):
    headers = ("metric", "baseline", "candidate", "dir", "status")
    str_rows = [
        (
            path,
            f"{base:g}",
            "-" if cand is None else f"{cand:g}",
            dirn,
            status,
        )
        for path, base, cand, dirn, status in rows
    ]
    widths = [
        max(len(headers[i]), max((len(r[i]) for r in str_rows), default=0))
        for i in range(len(headers))
    ]
    fmt = "  ".join(f"{{:<{w}}}" for w in widths)
    print(fmt.format(*headers))
    print("  ".join("-" * w for w in widths))
    for r in str_rows:
        print(fmt.format(*r))


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    parser.add_argument("--baseline", required=True, type=Path)
    parser.add_argument("--candidate", required=True, type=Path)
    parser.add_argument(
        "--threshold-pct",
        type=float,
        default=25.0,
        help="tolerance band around each gated baseline value (default 25)",
    )
    args = parser.parse_args(argv)

    baseline = json.loads(args.baseline.read_text())
    candidate = json.loads(args.candidate.read_text())

    rows, failures = compare(baseline, candidate, args.threshold_pct)
    print(f"bench_compare: {args.candidate} vs baseline {args.baseline} "
          f"(±{args.threshold_pct:g}%)\n")
    print_table(rows)
    if failures:
        print(f"\n{len(failures)} regression(s):", file=sys.stderr)
        for f in failures:
            print(f"  {f}", file=sys.stderr)
        return 1
    print("\nno regressions")
    return 0


if __name__ == "__main__":
    sys.exit(main())
