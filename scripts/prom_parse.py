#!/usr/bin/env python3
"""Prometheus text-exposition (v0.0.4) validator (stdlib only).

Parses and structurally validates the metrics rendering the serve tier
emits (``ServeMetrics::render_prometheus`` / ``BENCH_serve_metrics.prom``)
so CI catches a renderer regression before a real scraper does. Checks:

* **grammar** — every non-comment line is ``name[{labels}] value`` with a
  valid metric name, balanced/quoted labels, and a float-parseable value;
  comment lines are only ``# HELP name text`` / ``# TYPE name kind``;
* **declarations** — every sample belongs to a ``# TYPE``-declared family
  (histogram samples match their family via the ``_bucket``/``_sum``/
  ``_count`` suffixes), each family is declared exactly once, and
  counter families are named ``*_total``;
* **histogram laws** — bucket counts are cumulative (non-decreasing in
  file order), the ``+Inf`` bucket is present, terminal, and equals
  ``_count``, and ``_sum`` exists and is non-negative.

Exit is non-zero (with one line per violation) on any failure, so the CI
step is just ``python3 scripts/prom_parse.py BENCH_serve_metrics.prom``.
"""

from __future__ import annotations

import re
import sys
from pathlib import Path

METRIC_NAME = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")
LABELS = re.compile(r'^\{([a-zA-Z_][a-zA-Z0-9_]*="(?:[^"\\]|\\.)*",?)*\}$')
SAMPLE = re.compile(r"^(?P<name>[a-zA-Z_:][a-zA-Z0-9_:]*)(?P<labels>\{[^}]*\})? (?P<value>\S+)$")
KINDS = {"counter", "gauge", "histogram", "summary", "untyped"}


class Sample:
    def __init__(self, name: str, labels: str, value: float, line_no: int):
        self.name = name
        self.labels = labels
        self.value = value
        self.line_no = line_no


def family_of(sample_name: str, declared: dict) -> str | None:
    """Map a sample name to its declared family: exact, or histogram/summary
    suffix (``x_bucket``/``x_sum``/``x_count`` belong to family ``x``)."""
    if sample_name in declared:
        return sample_name
    for suffix in ("_bucket", "_sum", "_count"):
        if sample_name.endswith(suffix):
            base = sample_name[: -len(suffix)]
            if base in declared:
                return base
    return None


def validate(text: str) -> list[str]:
    """Return a list of violations (empty = valid)."""
    errors: list[str] = []
    declared: dict[str, str] = {}  # family -> kind
    samples: list[Sample] = []

    for i, line in enumerate(text.splitlines(), start=1):
        if not line.strip():
            continue
        if line.startswith("#"):
            parts = line.split(None, 3)
            if len(parts) < 3 or parts[1] not in ("HELP", "TYPE"):
                errors.append(f"line {i}: malformed comment {line!r}")
                continue
            if parts[1] == "TYPE":
                name, kind = parts[2], parts[3].strip() if len(parts) > 3 else ""
                if not METRIC_NAME.match(name):
                    errors.append(f"line {i}: bad metric name {name!r}")
                if kind not in KINDS:
                    errors.append(f"line {i}: unknown type {kind!r}")
                if name in declared:
                    errors.append(f"line {i}: family {name} declared twice")
                declared[name] = kind
            continue
        m = SAMPLE.match(line)
        if not m:
            errors.append(f"line {i}: unparseable sample {line!r}")
            continue
        if m["labels"] and not LABELS.match(m["labels"]):
            errors.append(f"line {i}: malformed labels {m['labels']!r}")
            continue
        try:
            value = float(m["value"].replace("+Inf", "inf").replace("-Inf", "-inf"))
        except ValueError:
            errors.append(f"line {i}: unparseable value {m['value']!r}")
            continue
        samples.append(Sample(m["name"], m["labels"] or "", value, i))

    by_family: dict[str, list[Sample]] = {}
    for s in samples:
        fam = family_of(s.name, declared)
        if fam is None:
            errors.append(f"line {s.line_no}: sample {s.name} has no # TYPE declaration")
            continue
        by_family.setdefault(fam, []).append(s)

    for fam, kind in declared.items():
        fam_samples = by_family.get(fam, [])
        if not fam_samples:
            errors.append(f"family {fam}: declared but has no samples")
            continue
        if kind == "counter":
            if not fam.endswith("_total"):
                errors.append(f"family {fam}: counters must be named *_total")
            for s in fam_samples:
                if s.value < 0:
                    errors.append(f"line {s.line_no}: counter {fam} is negative")
        elif kind == "histogram":
            errors.extend(check_histogram(fam, fam_samples))
    return errors


def check_histogram(fam: str, fam_samples: list) -> list[str]:
    errors: list[str] = []
    buckets = [s for s in fam_samples if s.name == f"{fam}_bucket"]
    sums = [s for s in fam_samples if s.name == f"{fam}_sum"]
    counts = [s for s in fam_samples if s.name == f"{fam}_count"]
    if len(sums) != 1 or len(counts) != 1:
        errors.append(f"family {fam}: needs exactly one _sum and one _count")
        return errors
    if sums[0].value < 0:
        errors.append(f"family {fam}: _sum is negative")
    if not buckets:
        errors.append(f"family {fam}: histogram has no _bucket samples")
        return errors
    les = []
    for b in buckets:
        m = re.search(r'le="([^"]*)"', b.labels)
        if not m:
            errors.append(f"line {b.line_no}: {fam}_bucket without an le label")
            return errors
        les.append((m.group(1), b.value, b.line_no))
    for (_, prev, _), (le, cur, line_no) in zip(les, les[1:]):
        if cur < prev:
            errors.append(
                f"line {line_no}: {fam}_bucket le={le} breaks cumulative "
                f"monotonicity ({cur} < {prev})"
            )
    bounds = [float(le.replace("+Inf", "inf")) for le, _, _ in les]
    if bounds != sorted(bounds):
        errors.append(f"family {fam}: bucket bounds are not ascending")
    if les[-1][0] != "+Inf":
        errors.append(f"family {fam}: the terminal bucket must be le=\"+Inf\"")
    elif les[-1][1] != counts[0].value:
        errors.append(
            f"family {fam}: +Inf bucket ({les[-1][1]}) != _count ({counts[0].value})"
        )
    return errors


def main(argv=None) -> int:
    args = (argv if argv is not None else sys.argv[1:]) or []
    if len(args) != 1:
        print("usage: prom_parse.py <exposition.prom>", file=sys.stderr)
        return 2
    text = Path(args[0]).read_text()
    errors = validate(text)
    if errors:
        print(f"{args[0]}: {len(errors)} violation(s):", file=sys.stderr)
        for e in errors:
            print(f"  {e}", file=sys.stderr)
        return 1
    n_samples = sum(
        1 for l in text.splitlines() if l.strip() and not l.startswith("#")
    )
    print(f"{args[0]}: valid exposition ({n_samples} samples)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
