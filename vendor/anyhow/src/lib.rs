//! Minimal `anyhow`-compatible error handling, vendored for hermetic builds.
//!
//! The offline crate registry this repo builds against is not guaranteed to
//! carry `anyhow`, so the workspace pins this path crate instead (see the
//! root `Cargo.toml`). It implements exactly the surface the coordinator
//! uses — `Result`, `Error`, `anyhow!`, `bail!`, and the `Context` trait on
//! both `Result` and `Option` — with `anyhow`-compatible semantics:
//!
//! * `Display` prints the outermost message; the alternate form (`{:#}`)
//!   prints the whole chain joined by `": "`.
//! * `Error` deliberately does **not** implement `std::error::Error`, so the
//!   blanket `From<E: std::error::Error>` impl cannot overlap the reflexive
//!   `From<Error>` conversion (the same trick the real crate uses).
//!
//! Swapping back to the real `anyhow` is a one-line change in the root
//! manifest; no call site needs to move.

use std::fmt;

/// Drop-in alias for `anyhow::Result`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// An error chain: outermost context first, root cause last.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Build an error from a printable message.
    pub fn msg(m: impl fmt::Display) -> Error {
        Error { chain: vec![m.to_string()] }
    }

    fn wrap(mut self, context: impl fmt::Display) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The error chain, outermost first (mirrors `anyhow::Error::chain`).
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }

    /// The innermost message (mirrors `anyhow::Error::root_cause`).
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            f.write_str(&self.chain.join(": "))
        } else {
            f.write_str(self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.chain.first().map(String::as_str).unwrap_or(""))?;
        for cause in &self.chain[1.min(self.chain.len())..] {
            write!(f, "\n\nCaused by:\n    {cause}")?;
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// `anyhow::Context`: attach context to failures of `Result` and `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E> Context<T> for std::result::Result<T, E>
where
    Error: From<E>,
{
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| Error::from(e).wrap(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| Error::from(e).wrap(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Build an [`Error`] from format arguments.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from format arguments.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*).into())
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing file")
    }

    #[test]
    fn display_and_alternate_chain() {
        let e: Error = Err::<(), _>(io_err()).context("reading config").unwrap_err();
        assert_eq!(format!("{e}"), "reading config");
        assert_eq!(format!("{e:#}"), "reading config: missing file");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("no value").unwrap_err();
        assert_eq!(format!("{e:#}"), "no value");
        assert_eq!(Some(7u32).context("no value").unwrap(), 7);
    }

    #[test]
    fn macros_and_question_mark() {
        fn inner() -> Result<()> {
            bail!("failed with code {}", 3);
        }
        let e = inner().unwrap_err();
        assert_eq!(e.to_string(), "failed with code 3");
        fn via_qmark() -> Result<u32> {
            let n: u32 = "17".parse()?;
            Ok(n)
        }
        assert_eq!(via_qmark().unwrap(), 17);
    }

    #[test]
    fn context_stacks_outermost_first() {
        let base: Result<()> = Err(anyhow!("root"));
        let e = base.context("mid").context("outer").unwrap_err();
        assert_eq!(format!("{e:#}"), "outer: mid: root");
        assert_eq!(e.root_cause(), "root");
        assert_eq!(e.chain().count(), 3);
    }

    #[test]
    fn error_is_send_sync() {
        fn assert_send_sync<T: Send + Sync + 'static>() {}
        assert_send_sync::<Error>();
    }
}
