//! API-compatible stub of the `xla` crate (xla-rs PJRT bindings).
//!
//! This container image carries no libxla/PJRT shared objects, so the real
//! bindings cannot link here. This stub exposes the exact API surface
//! `ngdb_zoo::runtime::pjrt` compiles against, with every entry point that
//! would touch native code returning [`Error::Unavailable`] at runtime.
//! That keeps `cargo build/clippy/test --features pjrt` hermetic and
//! compile-checked in CI while the execution path stays honest: opening a
//! `PjrtRuntime` fails with a clear message instead of segfaulting.
//!
//! On a machine with the real XLA toolchain, point the workspace manifest's
//! `xla` entry at the actual `xla` crate (crates.io or git) — the call sites
//! are written against the genuine xla-rs API and need no changes.

use std::fmt;

/// Error for every stubbed native call.
#[derive(Debug)]
pub enum Error {
    /// Native PJRT/XLA libraries are not present in this build.
    Unavailable(&'static str),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Unavailable(what) => write!(
                f,
                "{what}: XLA PJRT native bindings are not available in this build \
                 (the `xla` dependency is the in-repo stub; install the real \
                 xla-rs crate and its shared libraries to execute artifacts)"
            ),
        }
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Element dtypes understood by the literal constructors.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    F32,
}

/// Host-side literal value (stub: never constructed successfully).
#[derive(Debug, Clone)]
pub struct Literal {
    _private: (),
}

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        _ty: ElementType,
        _dims: &[usize],
        _data: &[u8],
    ) -> Result<Literal> {
        Err(Error::Unavailable("Literal::create_from_shape_and_untyped_data"))
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(Error::Unavailable("Literal::to_vec"))
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(Error::Unavailable("Literal::to_tuple"))
    }
}

/// Parsed HLO module (stub).
#[derive(Debug)]
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(Error::Unavailable("HloModuleProto::from_text_file"))
    }
}

/// XLA computation handle (stub).
#[derive(Debug)]
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// Device-resident buffer (stub).
#[derive(Debug)]
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::Unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// Compiled executable (stub).
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::Unavailable("PjRtLoadedExecutable::execute"))
    }

    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::Unavailable("PjRtLoadedExecutable::execute_b"))
    }
}

/// PJRT client (stub: construction fails up front with a clear message).
#[derive(Debug)]
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::Unavailable("PjRtClient::cpu"))
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::Unavailable("PjRtClient::compile"))
    }

    pub fn buffer_from_host_buffer(
        &self,
        _data: &[f32],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        Err(Error::Unavailable("PjRtClient::buffer_from_host_buffer"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_native_entry_point_reports_unavailable() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
        let err = Literal::create_from_shape_and_untyped_data(ElementType::F32, &[1], &[0; 4])
            .unwrap_err();
        assert!(err.to_string().contains("not available"));
    }
}
